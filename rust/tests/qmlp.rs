//! ISSUE 9 satellite: fixed-point unit tests for the quantized-MLP
//! backend — Taylor-activation monotonicity and error bounds against
//! the f64 reference, Q-format saturation/rounding edge cases
//! (`i32::MIN`/`MAX`, zero scale rejected at load), and the
//! verdict-preserving `from_bnn` quantization fuzzed against the BNN
//! executor.

use n3ic::bnn::{BnnExecutor, BnnLayer, BnnModel};
use n3ic::net::traffic::Rng;
use n3ic::qmlp::{
    Activation, QFormat, QmlpError, QmlpExecutor, QuantLayer, QuantMlp, QMLP_FRAC_BITS,
};

/// The f64 reference the fixed-point sigmoid approximates:
/// `½ + x/4 − x³/48` on the clamp range.
fn taylor_f64(x: f64) -> f64 {
    let x = x.clamp(-2.0, 2.0);
    0.5 + x / 4.0 - x * x * x / 48.0
}

fn sigmoid_f64(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

#[test]
fn taylor_sigmoid_is_monotone_across_and_beyond_the_clamp_range() {
    let q = QFormat::new(8).unwrap();
    let one = q.one();
    let mut prev = q.sigmoid_taylor(-3 * one);
    for x in (-3 * one + 1)..=(3 * one) {
        let y = q.sigmoid_taylor(x);
        assert!(y >= prev, "x={x}: {y} < {prev}");
        prev = y;
    }
    // The clamp makes the tails flat, not wrapped.
    assert_eq!(q.sigmoid_taylor(3 * one), q.sigmoid_taylor(2 * one));
    assert_eq!(q.sigmoid_taylor(i32::MAX), q.sigmoid_taylor(2 * one));
    assert_eq!(q.sigmoid_taylor(i32::MIN), q.sigmoid_taylor(-2 * one));
}

#[test]
fn taylor_sigmoid_fixed_points_and_odd_symmetry_at_every_resolution() {
    for f in [1u32, 4, 8, 12, 16] {
        let q = QFormat::new(f).unwrap();
        let one = q.one();
        assert_eq!(q.sigmoid_taylor(0), one / 2, "f={f}: σ̃(0) must be exactly ½");
        for x in [1, 2, one / 2, one, 2 * one - 1, 2 * one, 3 * one, i32::MAX] {
            let pos = q.sigmoid_taylor(x);
            let neg = q.sigmoid_taylor(-x);
            assert_eq!(pos + neg, one, "f={f} x={x}: σ̃(x)+σ̃(−x) must be exactly 1");
        }
        // Saturated extremes mirror too (both clamp to ±2).
        assert_eq!(q.sigmoid_taylor(i32::MAX) + q.sigmoid_taylor(i32::MIN), one, "f={f}");
    }
}

#[test]
fn taylor_sigmoid_error_bounds_against_the_f64_references() {
    let q = QFormat::new(12).unwrap();
    let one = q.one();
    let ulp = 1.0 / one as f64;
    let mut max_vs_sigmoid = 0.0f64;
    for x in -2 * one..=2 * one {
        let got = q.to_f64(q.sigmoid_taylor(x));
        let xf = q.to_f64(x);
        // Against the exact polynomial at representable points: one
        // rounded division ⇒ at most half an ulp of error.
        assert!((got - taylor_f64(xf)).abs() <= ulp, "x={xf}: {got}");
        max_vs_sigmoid = max_vs_sigmoid.max((got - sigmoid_f64(xf)).abs());
    }
    // Against the true sigmoid: the degree-3 truncation peaks near the
    // clamp edge (≈0.0475 at ±2); the bound must hold but not be vacuous.
    assert!(max_vs_sigmoid <= 0.05, "max error {max_vs_sigmoid}");
    assert!(max_vs_sigmoid > 0.04, "suspiciously small error {max_vs_sigmoid}");
}

#[test]
fn q_format_rounding_and_saturation_edges() {
    let q = QFormat::new(8).unwrap();
    let one = q.one();
    assert_eq!(one, 256);

    // Quantize: half-away rounding, saturation, non-finite rejection.
    assert_eq!(q.quantize(0.5).unwrap(), one / 2);
    assert_eq!(q.quantize(0.001953125).unwrap(), 1, "0.5 steps round away from zero");
    assert_eq!(q.quantize(-0.001953125).unwrap(), -1);
    assert_eq!(q.quantize(1e30).unwrap(), i32::MAX, "overflow saturates");
    assert_eq!(q.quantize(-1e30).unwrap(), i32::MIN);
    assert!(matches!(q.quantize(f64::NAN), Err(QmlpError::NonFinite(_))));
    assert!(matches!(q.quantize(f64::INFINITY), Err(QmlpError::NonFinite(_))));
    assert_eq!(q.to_f64(q.quantize(-1.5).unwrap()), -1.5);

    // Multiply: Q(2f) product rounded back, saturating at the rails.
    assert_eq!(q.mul(one / 2, one / 2), one / 4);
    assert_eq!(q.mul(3, 128), 2, "384/256 rounds up");
    assert_eq!(q.mul(-3, 128), -2, "symmetric rounding");
    assert_eq!(q.mul(i32::MAX, one), i32::MAX);
    assert_eq!(q.mul(i32::MIN, one), i32::MIN);
    assert_eq!(q.mul(i32::MIN, i32::MIN), i32::MAX, "−·− saturates high");
    assert_eq!(q.mul(i32::MAX, i32::MIN), i32::MIN, "+·− saturates low");

    // Saturating add at the rails.
    assert_eq!(q.sat_add(i32::MAX, 1), i32::MAX);
    assert_eq!(q.sat_add(i32::MIN, -1), i32::MIN);
    assert_eq!(q.sat_add(100, -50), 50);
}

#[test]
fn bad_scales_and_bad_frac_bits_are_load_time_errors() {
    assert!(matches!(QFormat::from_scale(0.0), Err(QmlpError::BadScale(_))), "zero scale");
    assert!(matches!(QFormat::from_scale(-0.25), Err(QmlpError::BadScale(_))));
    assert!(matches!(QFormat::from_scale(f64::NAN), Err(QmlpError::BadScale(_))));
    assert!(matches!(QFormat::from_scale(0.3), Err(QmlpError::BadScale(_))), "not a power of 2");
    assert!(matches!(QFormat::from_scale(1.0), Err(QmlpError::BadScale(_))), "f=0 out of range");
    assert_eq!(QFormat::from_scale(0.00390625).unwrap().frac_bits(), 8);
    assert_eq!(QFormat::from_scale(0.25).unwrap().frac_bits(), 2);
    assert_eq!(QFormat::from_scale(2f64.powi(-16)).unwrap().frac_bits(), 16);
    assert!(matches!(QFormat::new(0), Err(QmlpError::BadFracBits(0))));
    assert!(matches!(QFormat::new(17), Err(QmlpError::BadFracBits(17))));
    assert_eq!(QFormat::new(QMLP_FRAC_BITS).unwrap().one(), 256);
}

#[test]
fn layer_loading_rejects_non_finite_weights_and_bad_shapes() {
    let q = QFormat::new(8).unwrap();
    let ok = QuantLayer::quantized(2, 3, &[0.5; 6], &[0.0; 2], Activation::Identity, q);
    assert!(ok.is_ok());
    let nan = QuantLayer::quantized(
        2,
        3,
        &[0.5, f64::NAN, 0.5, 0.5, 0.5, 0.5],
        &[0.0; 2],
        Activation::Identity,
        q,
    );
    assert!(matches!(nan, Err(QmlpError::NonFinite(_))));
    let bad_w = QuantLayer::new(2, 3, vec![0; 5], vec![0; 2], Activation::Identity);
    assert!(matches!(bad_w, Err(QmlpError::Shape(_))));
    let bad_b = QuantLayer::new(2, 3, vec![0; 6], vec![0; 3], Activation::Identity);
    assert!(matches!(bad_b, Err(QmlpError::Shape(_))));
    let empty = QuantLayer::new(0, 3, vec![], vec![], Activation::Identity);
    assert!(matches!(empty, Err(QmlpError::Shape(_))));
}

#[test]
fn network_chaining_allows_padding_only_through_sign_layers() {
    let q = QFormat::new(8).unwrap();
    let layer = |neurons: usize, inputs: usize, act: Activation| {
        QuantLayer::new(neurons, inputs, vec![q.one(); neurons * inputs], vec![0; neurons], act)
            .unwrap()
    };
    // 4 sign neurons padded up to a 32-wide next layer: the BNN word
    // convention, allowed.
    let padded = QuantMlp::new(
        "pad",
        q,
        vec![layer(4, 8, Activation::TaylorSign), layer(2, 32, Activation::Identity)],
    );
    assert!(padded.is_ok());
    // The same hand-off without a sign activation would pad continuous
    // values with −1 — rejected.
    let continuous = QuantMlp::new(
        "cont",
        q,
        vec![layer(4, 8, Activation::TaylorSigmoid), layer(2, 32, Activation::Identity)],
    );
    assert!(matches!(continuous, Err(QmlpError::Shape(_))));
    // A narrowing hand-off drops neurons — always rejected.
    let narrow = QuantMlp::new(
        "narrow",
        q,
        vec![layer(4, 8, Activation::TaylorSign), layer(2, 3, Activation::Identity)],
    );
    assert!(matches!(narrow, Err(QmlpError::Shape(_))));
    assert!(matches!(QuantMlp::new("empty", q, vec![]), Err(QmlpError::Shape(_))));
}

/// The heart of the backend's conformance claim: quantizing a random
/// BNN yields the same classifier, input for input, and the final-layer
/// scores are exactly the affine image `(2s − W)·one` of the BNN's
/// popcount scores.
#[test]
fn from_bnn_is_verdict_identical_across_fuzzed_models() {
    const FUZZ_MODELS: u64 = 20;
    let mut rng = Rng::new(0x0F1D0);
    for m in 0..FUZZ_MODELS {
        let in_bits = 1 + rng.below(260) as usize;
        let depth = 1 + rng.below(3) as usize;
        let arch: Vec<usize> = (0..depth).map(|_| 1 + rng.below(40) as usize).collect();
        let model = BnnModel::random(&format!("fq{m}"), in_bits, &arch, 0xF1D0 + m);
        let mut bnn = BnnExecutor::new(model.clone());
        let mut qx = QmlpExecutor::from_bnn(&model, QMLP_FRAC_BITS).unwrap();
        let one = qx.mlp().q().one() as i64;
        let w_last = qx.mlp().layers().last().unwrap().inputs as i64;
        let mut bnn_scores = vec![0i32; model.out_neurons()];
        let mut q_scores = vec![0i32; model.out_neurons()];
        for i in 0..12u64 {
            let x = BnnLayer::random(1, in_bits, 3_000 + m * 100 + i).words;
            assert_eq!(qx.classify(&x), bnn.classify(&x), "fq{m} input {i}");
            bnn.infer(&x, &mut bnn_scores);
            qx.infer_bits(&x, &mut q_scores);
            for (n, (&s, &sq)) in bnn_scores.iter().zip(&q_scores).enumerate() {
                assert_eq!(sq as i64, (2 * s as i64 - w_last) * one, "fq{m} neuron {n}");
            }
        }
    }
}

//! ISSUE 4 acceptance: **no inference ever observes a torn or
//! mixed-version model** under concurrent publishes — not on the
//! single-input path, not across `ShardedEngine` shards, not through
//! the routed pipeline.
//!
//! The proof technique everywhere: models are keyed to their version
//! (`model for version v = BnnModel::random(name, …, seed_base + v)`),
//! the expected verdict of every (version, input) pair is precomputed,
//! and each classification's verdict must match *the version its tag
//! claims*.  A reader that saw half-swapped weights, or a shard that
//! ran a different version than its batch's tag, produces a verdict
//! that matches no claim — the assertions below would trip.
//!
//! A deterministic seeded-schedule variant replays the same
//! publish/classify interleavings single-threaded, so any failure here
//! reproduces exactly from its seed.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use n3ic::bnn::{infer_packed, BnnLayer, BnnModel, MultiModelExecutor, RegistryHandle};
use n3ic::coordinator::{
    BackendFactory, ModelRouter, OutputSelector, PacketEvent, ServeBuilder, TriggerCondition,
};
use n3ic::net::packet::{Packet, Proto};
use n3ic::net::traffic::{CbrSpec, Rng};

const IN_BITS: usize = 256;
const ARCH: [usize; 3] = [32, 16, 2];

/// The model a slot serves at `version` — the version-keyed weights the
/// whole harness proves against.
fn model_v(name: &str, seed_base: u64, version: u64) -> BnnModel {
    BnnModel::random(name, IN_BITS, &ARCH, seed_base + version)
}

fn inputs(n: usize, seed: u64) -> Vec<Vec<u32>> {
    (0..n)
        .map(|i| BnnLayer::random(1, IN_BITS, seed + i as u64).words)
        .collect()
}

/// `expected[v - 1][i]` = verdict of input `i` under version `v`.
fn expected_table(name: &str, seed_base: u64, versions: u64, xs: &[Vec<u32>]) -> Vec<Vec<usize>> {
    (1..=versions)
        .map(|v| {
            let m = model_v(name, seed_base, v);
            xs.iter().map(|x| infer_packed(&m, x)).collect()
        })
        .collect()
}

#[test]
fn hammered_single_input_reads_always_match_their_tag() {
    const VERSIONS: u64 = 10;
    let xs = inputs(16, 7_000);
    let expected = Arc::new(expected_table("anomaly", 100, VERSIONS, &xs));
    let xs = Arc::new(xs);

    let reg = RegistryHandle::new();
    reg.publish("anomaly", &model_v("anomaly", 100, 1)).unwrap();
    // Stored *before* the matching publish, so `published` is always ≥
    // any version a reader can observe.
    let published = Arc::new(AtomicU64::new(1));
    let stop = Arc::new(AtomicBool::new(false));

    let writer = {
        let reg = reg.clone();
        let published = Arc::clone(&published);
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            for v in 2..=VERSIONS {
                thread::sleep(Duration::from_millis(2));
                published.store(v, Ordering::SeqCst);
                reg.publish("anomaly", &model_v("anomaly", 100, v)).unwrap();
            }
            stop.store(true, Ordering::SeqCst);
        })
    };

    let readers: Vec<_> = (0..2)
        .map(|_| {
            let reg = reg.clone();
            let xs = Arc::clone(&xs);
            let expected = Arc::clone(&expected);
            let stop = Arc::clone(&stop);
            let published = Arc::clone(&published);
            thread::spawn(move || {
                let names = vec!["anomaly".to_string()];
                let mut exec = MultiModelExecutor::new(&reg, &names, 100.0).unwrap();
                let mut last_version = 0u64;
                let mut reads = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    for (i, x) in xs.iter().enumerate() {
                        let (class, tag) = exec.classify(0, x);
                        let v = tag.version();
                        // Tagged version is a published one …
                        assert!(v >= 1 && v <= published.load(Ordering::SeqCst));
                        // … the verdict matches exactly that version's
                        // weights (torn weights would match neither) …
                        assert_eq!(class, expected[(v - 1) as usize][i], "input {i} under v{v}");
                        // … and versions never run backwards per reader.
                        assert!(v >= last_version, "version regressed {last_version} → {v}");
                        last_version = v;
                        reads += 1;
                    }
                }
                reads
            })
        })
        .collect();

    writer.join().unwrap();
    let total: u64 = readers.into_iter().map(|r| r.join().unwrap()).sum();
    assert!(total > 0);
}

#[test]
fn hammered_sharded_batches_never_mix_versions_across_shards() {
    const VERSIONS: u64 = 10;
    // More inputs than shards × TILE so every shard gets real work.
    let xs = inputs(37, 9_000);
    let expected = Arc::new(expected_table("anomaly", 200, VERSIONS, &xs));
    let xs = Arc::new(xs);

    let reg = RegistryHandle::new();
    reg.publish("anomaly", &model_v("anomaly", 200, 1)).unwrap();
    let published = Arc::new(AtomicU64::new(1));
    let stop = Arc::new(AtomicBool::new(false));

    let writer = {
        let reg = reg.clone();
        let published = Arc::clone(&published);
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            for v in 2..=VERSIONS {
                thread::sleep(Duration::from_millis(2));
                published.store(v, Ordering::SeqCst);
                reg.publish("anomaly", &model_v("anomaly", 200, v)).unwrap();
            }
            stop.store(true, Ordering::SeqCst);
        })
    };

    let names = vec!["anomaly".to_string()];
    let mut exec = MultiModelExecutor::new(&reg, &names, 100.0).unwrap().sharded(4);
    let mut classes = Vec::new();
    let mut batches = 0u64;
    while !stop.load(Ordering::SeqCst) {
        let tag = exec.classify_batch(0, &xs, &mut classes);
        let v = tag.version();
        assert!(v >= 1 && v <= published.load(Ordering::SeqCst));
        assert_eq!(classes.len(), xs.len());
        // Every verdict of the batch — whichever of the 4 shard workers
        // scored it — must match the single tagged version.  A shard
        // that ran under different weights than its siblings would
        // disagree with this table.
        for (i, &c) in classes.iter().enumerate() {
            assert_eq!(c, expected[(v - 1) as usize][i], "batch {batches}, input {i}, v{v}");
        }
        batches += 1;
    }
    writer.join().unwrap();
    assert!(batches > 0);
}

/// Seeded, single-threaded replay of publish/classify interleavings:
/// the same invariants as the hammer tests, plus the synchronous
/// freshness guarantee (a pin after `publish` returns *must* observe
/// the new version).  Any failure reproduces exactly from `SEED`.
#[test]
fn deterministic_seeded_schedule_replays_swap_interleavings() {
    const SEED: u64 = 0x5EED_0004;
    const STEPS: usize = 400;
    const MAX_VERSIONS: u64 = 64;

    let xs = inputs(12, 11_000);
    let expected = expected_table("anomaly", 300, MAX_VERSIONS, &xs);

    let reg = RegistryHandle::new();
    reg.publish("anomaly", &model_v("anomaly", 300, 1)).unwrap();
    let names = vec!["anomaly".to_string()];
    let mut single = MultiModelExecutor::new(&reg, &names, 100.0).unwrap();
    let mut sharded = MultiModelExecutor::new(&reg, &names, 100.0).unwrap().sharded(3);

    let mut rng = Rng::new(SEED);
    let mut cur = 1u64;
    let mut classes = Vec::new();
    let (mut publishes, mut singles, mut batches) = (0u64, 0u64, 0u64);
    for step in 0..STEPS {
        match rng.below(10) {
            0 | 1 => {
                if cur < MAX_VERSIONS {
                    cur += 1;
                    reg.publish("anomaly", &model_v("anomaly", 300, cur)).unwrap();
                    publishes += 1;
                }
            }
            2..=5 => {
                let i = rng.below(xs.len() as u64) as usize;
                let (class, tag) = single.classify(0, &xs[i]);
                // Freshness: publish is synchronous, the next pin sees it.
                assert_eq!(tag.version(), cur, "step {step}");
                assert_eq!(class, expected[(cur - 1) as usize][i], "step {step}");
                singles += 1;
            }
            _ => {
                let tag = sharded.classify_batch(0, &xs, &mut classes);
                assert_eq!(tag.version(), cur, "step {step}");
                for (i, &c) in classes.iter().enumerate() {
                    assert_eq!(c, expected[(cur - 1) as usize][i], "step {step}, input {i}");
                }
                batches += 1;
            }
        }
    }
    // The seeded walk must actually exercise all three operations.
    assert!(publishes > 10, "schedule degenerate: {publishes} publishes");
    assert!(singles > 50, "schedule degenerate: {singles} single reads");
    assert!(batches > 50, "schedule degenerate: {batches} batch reads");
    assert_eq!(reg.swap_count("anomaly"), publishes);
}

/// Build a payload-carrying event whose flow id encodes which input it
/// carries, so pipeline verdicts can be checked against the version
/// their tag claims.
fn payload_event(flow: u32, dst_port: u16, input: &[u32], ts_ns: f64) -> PacketEvent {
    PacketEvent {
        packet: Packet {
            ts_ns,
            src_ip: 0x0A00_0000 + flow,
            dst_ip: 0x0B00_0000 + dst_port as u32,
            src_port: 2000 + (flow % 1000) as u16,
            dst_port,
            proto: Proto::Tcp,
            size: 256,
            tcp_flags: 0x10,
        },
        payload_words: Some(input.to_vec()),
    }
}

/// Same id the service derives, so verdicts map back to their input.
fn id_of(ev: &PacketEvent) -> u64 {
    ((ev.packet.src_ip as u64) << 32) | ev.packet.dst_ip as u64
}

#[test]
fn pipeline_readers_survive_concurrent_publishes_with_consistent_tags() {
    const VERSIONS: u64 = 8;
    const EVENTS: usize = 6000;
    let xs = inputs(24, 13_000);
    let exp_a = expected_table("anomaly", 400, VERSIONS, &xs);
    let exp_t = expected_table("traffic-class", 500, VERSIONS, &xs);

    let reg = RegistryHandle::new();
    reg.publish("anomaly", &model_v("anomaly", 400, 1)).unwrap();
    reg.publish("traffic-class", &model_v("traffic-class", 500, 1)).unwrap();
    let pub_a = Arc::new(AtomicU64::new(1));
    let pub_t = Arc::new(AtomicU64::new(1));
    let stop = Arc::new(AtomicBool::new(false));

    let writer = {
        let reg = reg.clone();
        let (pub_a, pub_t) = (Arc::clone(&pub_a), Arc::clone(&pub_t));
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            for v in 2..=VERSIONS {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                thread::sleep(Duration::from_millis(1));
                pub_a.store(v, Ordering::SeqCst);
                reg.publish("anomaly", &model_v("anomaly", 400, v)).unwrap();
                thread::sleep(Duration::from_millis(1));
                pub_t.store(v, Ordering::SeqCst);
                reg.publish("traffic-class", &model_v("traffic-class", 500, v)).unwrap();
            }
        })
    };

    // DstPort rules: port 1 → anomaly, port 2 → traffic-class; every
    // packet of a routed port triggers, with a payload input it names.
    let router = ModelRouter::rules(vec![
        (TriggerCondition::DstPort(1), "anomaly".into()),
        (TriggerCondition::DstPort(2), "traffic-class".into()),
    ]);
    let mut id_to_input = HashMap::new();
    let events: Vec<PacketEvent> = (0..EVENTS)
        .map(|k| {
            let flow = k as u32;
            let port = 1 + (k % 2) as u16;
            let input_idx = k % xs.len();
            let ev = payload_event(flow, port, &xs[input_idx], 10.0 * k as f64);
            id_to_input.insert(id_of(&ev), input_idx);
            ev
        })
        .collect();

    let names = router.model_names().to_vec();
    let report = ServeBuilder::new()
        .backend(BackendFactory::registry(&reg, &names, 100.0, 3).unwrap())
        .router(router)
        .output(OutputSelector::Memory)
        .batching(16, 1e5)
        .pipeline(3)
        .build()
        .unwrap()
        .run(events)
        .unwrap();
    stop.store(true, Ordering::SeqCst);
    writer.join().unwrap();

    // Every routed packet produced exactly one tagged verdict.
    assert_eq!(report.stats.inferences, EVENTS as u64);
    assert_eq!(report.tagged.len(), EVENTS);
    for t in &report.tagged {
        let i = id_to_input[&t.id];
        let (exp, published) = match t.tag.name() {
            "anomaly" => (&exp_a, &pub_a),
            "traffic-class" => (&exp_t, &pub_t),
            other => panic!("unexpected model {other}"),
        };
        let v = t.tag.version();
        // Tag names a published version, and the verdict matches that
        // exact version's weights — across batching, sharding, and
        // whatever publish raced this run.
        assert!(v >= 1 && v <= published.load(Ordering::SeqCst), "{}", t.tag);
        assert_eq!(t.class, exp[(v - 1) as usize][i], "flow {} under {}", t.id, t.tag);
    }
    // Per-model accounting is complete, and the reported swap counts
    // are registry snapshots taken inside run() — the writer may land
    // a few more publishes between that snapshot and its join, so the
    // snapshot is bounded by the final count, not equal to it.
    let pm = &report.stats.per_model;
    assert_eq!(pm.values().map(|m| m.inferences).sum::<u64>(), EVENTS as u64);
    assert!(pm["anomaly"].swaps <= reg.swap_count("anomaly"));
    assert!(pm["traffic-class"].swaps <= reg.swap_count("traffic-class"));
    assert!(reg.swap_count("anomaly") <= VERSIONS - 1);
}

/// ISSUE 10 (promotion-gate substrate): `rollback` to *any* previously
/// snapshotted epoch — not just the immediately preceding one — must
/// republish exactly that epoch's weights under a strictly newer
/// version.  The gate's probation path leans on this: it snapshots
/// `current()` before publishing a candidate and may unwind several
/// promotions deep.
#[test]
fn rollback_replays_any_snapshotted_depth_with_monotone_versions() {
    let xs = inputs(12, 15_000);
    let expected = expected_table("anomaly", 600, 3, &xs);

    let reg = RegistryHandle::new();
    reg.publish("anomaly", &model_v("anomaly", 600, 1)).unwrap();
    let e1 = reg.current("anomaly").unwrap();
    reg.publish("anomaly", &model_v("anomaly", 600, 2)).unwrap();
    let e2 = reg.current("anomaly").unwrap();
    reg.publish("anomaly", &model_v("anomaly", 600, 3)).unwrap();
    assert_eq!(e1.version(), 1);
    assert_eq!(e2.version(), 2);

    let names = vec!["anomaly".to_string()];
    let mut exec = MultiModelExecutor::new(&reg, &names, 100.0).unwrap();

    // Depth 1: roll back past v3 to the v2 snapshot → new version 4,
    // serving v2's exact weights.
    let tag = reg.rollback("anomaly", &e2).unwrap();
    assert_eq!(tag.version(), 4, "rollback must mint a NEW version, never rewind");
    for (i, x) in xs.iter().enumerate() {
        let (class, tag) = exec.classify(0, x);
        assert_eq!(tag.version(), 4);
        assert_eq!(class, expected[1][i], "v4 must serve v2's weights (input {i})");
    }

    // Depth 2: roll back again, two publishes deep, to the v1 snapshot.
    let tag = reg.rollback("anomaly", &e1).unwrap();
    assert_eq!(tag.version(), 5);
    for (i, x) in xs.iter().enumerate() {
        let (class, tag) = exec.classify(0, x);
        assert_eq!(tag.version(), 5);
        assert_eq!(class, expected[0][i], "v5 must serve v1's weights (input {i})");
    }

    // The snapshots themselves are immutable: rolling back to e2 again
    // still works even though the registry has moved on since.
    let tag = reg.rollback("anomaly", &e2).unwrap();
    assert_eq!(tag.version(), 6);
    let (class, tag) = exec.classify(0, &xs[0]);
    assert_eq!(tag.version(), 6);
    assert_eq!(class, expected[1][0]);

    // Slot creation isn't a swap; the 2 follow-up publishes and the
    // 3 rollbacks each are.
    assert_eq!(reg.swap_count("anomaly"), 5);
}

/// Interleave publish / touch / rollback and check every sharded batch
/// verdict against the weights *its tag's version* was installed with.
/// `touch` republishes the same weights, `rollback` republishes old
/// weights — a reader that conflated "version" with "weights identity"
/// would trip on either.
#[test]
fn sharded_reads_stay_tag_consistent_across_touch_and_rollback() {
    let xs = inputs(17, 17_000);
    let expected = expected_table("anomaly", 700, 3, &xs);
    // weights_of[v - 1] = which of the 3 weight sets version v serves.
    let mut weights_of: Vec<usize> = Vec::new();

    let reg = RegistryHandle::new();
    reg.publish("anomaly", &model_v("anomaly", 700, 1)).unwrap();
    weights_of.push(1);
    let pre = reg.current("anomaly").unwrap();

    let names = vec!["anomaly".to_string()];
    let mut exec = MultiModelExecutor::new(&reg, &names, 100.0).unwrap().sharded(3);
    let mut classes = Vec::new();
    let mut check = |exec: &mut MultiModelExecutor, weights_of: &[usize]| {
        let tag = exec.classify_batch(0, &xs, &mut classes);
        let v = tag.version() as usize;
        assert_eq!(v, weights_of.len(), "freshness: pin after install sees it");
        let w = weights_of[v - 1];
        for (i, &c) in classes.iter().enumerate() {
            assert_eq!(c, expected[w - 1][i], "v{v} serves weight set {w} (input {i})");
        }
    };

    check(&mut exec, &weights_of);
    reg.publish("anomaly", &model_v("anomaly", 700, 2)).unwrap();
    weights_of.push(2);
    check(&mut exec, &weights_of);
    reg.touch("anomaly").unwrap(); // v3: same weights as v2
    weights_of.push(2);
    check(&mut exec, &weights_of);
    reg.rollback("anomaly", &pre).unwrap(); // v4: v1's weights again
    weights_of.push(1);
    check(&mut exec, &weights_of);
    reg.publish("anomaly", &model_v("anomaly", 700, 3)).unwrap();
    weights_of.push(3);
    check(&mut exec, &weights_of);

    assert_eq!(reg.swap_count("anomaly"), 4);
}

/// Acceptance: a pipeline run with two named models yields per-model
/// verdict histograms identical to two standalone single-model runs on
/// the same seeded traffic.
#[test]
fn two_model_pipeline_matches_two_standalone_single_model_runs() {
    let m_a = BnnModel::random("anomaly", IN_BITS, &ARCH, 61);
    let m_t = BnnModel::random("traffic-class", IN_BITS, &ARCH, 62);
    let reg = RegistryHandle::new();
    reg.publish("anomaly", &m_a).unwrap();
    reg.publish("traffic-class", &m_t).unwrap();

    // Seeded CBR traffic: TCP flows go to 443 (anomaly), UDP to 53
    // (traffic-class) — disjoint per-flow routes.
    let events: Vec<PacketEvent> = PacketEvent::cbr_burst(
        CbrSpec { gbps: 40.0, pkt_size: 256 },
        80,
        17,
        8000,
    );
    let router = ModelRouter::rules(vec![
        (TriggerCondition::DstPort(443), "anomaly".into()),
        (TriggerCondition::DstPort(53), "traffic-class".into()),
    ]);

    let names = router.model_names().to_vec();
    let report = ServeBuilder::new()
        .backend(BackendFactory::registry(&reg, &names, 100.0, 2).unwrap())
        .router(router)
        .output(OutputSelector::Memory)
        .batching(8, 1e6)
        .pipeline(3)
        .build()
        .unwrap()
        .run(events.iter().cloned())
        .unwrap();

    // Standalone single-model reference runs over the same events.
    let standalone = |model: &BnnModel, port: u16| {
        let rep = ServeBuilder::new()
            .backend(BackendFactory::single("fpga", model.clone()).unwrap())
            .trigger(TriggerCondition::DstPort(port))
            .output(OutputSelector::Memory)
            .build()
            .unwrap()
            .run(events.iter().cloned())
            .unwrap();
        let mut mem = rep.sink.memory;
        mem.sort_unstable();
        (rep.stats.classes, rep.stats.inferences, mem)
    };
    let (hist_a, inf_a, mem_a) = standalone(&m_a, 443);
    let (hist_t, inf_t, mem_t) = standalone(&m_t, 53);

    let pad = |v: &[u64], n: usize| {
        let mut v = v.to_vec();
        if v.len() < n {
            v.resize(n, 0);
        }
        v
    };
    let pm = &report.stats.per_model;
    let n = report.stats.classes.len().max(hist_a.len()).max(hist_t.len());
    assert_eq!(pad(&pm["anomaly"].classes, n), pad(&hist_a, n));
    assert_eq!(pad(&pm["traffic-class"].classes, n), pad(&hist_t, n));
    assert_eq!(pm["anomaly"].inferences, inf_a);
    assert_eq!(pm["traffic-class"].inferences, inf_t);
    assert_eq!(report.stats.inferences, inf_a + inf_t);

    // Per-flow verdict multisets match too, split by model.
    let mut routed_a: Vec<(u64, usize)> = Vec::new();
    let mut routed_t: Vec<(u64, usize)> = Vec::new();
    for t in &report.tagged {
        match t.tag.name() {
            "anomaly" => routed_a.push((t.id, t.class)),
            _ => routed_t.push((t.id, t.class)),
        }
    }
    routed_a.sort_unstable();
    routed_t.sort_unstable();
    assert_eq!(routed_a, mem_a);
    assert_eq!(routed_t, mem_t);
}

//! Bit-exactness of the batched inference subsystem (ISSUE 1 acceptance:
//! asserted, not eyeballed): [`BatchKernel`] and [`ShardedEngine`] must
//! agree with `BnnExecutor::infer` on every score and verdict, across
//! odd `in_words`, odd batch sizes (1, 7, 33, 1024), ragged final tiles,
//! and shard counts exceeding the batch size.
//!
//! Property-style over the crate's deterministic RNG (offline build: no
//! proptest), same convention as `tests/integration.rs`.

use n3ic::bnn::{argmax, BatchKernel, BnnExecutor, BnnLayer, BnnModel, ShardedEngine, TILE};

fn batch_inputs(in_bits: usize, n: usize, seed: u64) -> Vec<Vec<u32>> {
    (0..n)
        .map(|i| BnnLayer::random(1, in_bits, seed + i as u64).words)
        .collect()
}

/// Reference scores + classes via the single-input executor.
fn reference(model: &BnnModel, inputs: &[Vec<u32>]) -> (Vec<i32>, Vec<usize>) {
    let mut exec = BnnExecutor::new(model.clone());
    let mut scores = vec![0i32; model.out_neurons()];
    let mut flat = Vec::with_capacity(inputs.len() * scores.len());
    let mut classes = Vec::with_capacity(inputs.len());
    for x in inputs {
        exec.infer(x, &mut scores);
        flat.extend_from_slice(&scores);
        classes.push(argmax(&scores));
    }
    (flat, classes)
}

/// Shapes chosen to hit the corner cases: odd in_words (152 b → 5 words),
/// non-multiple-of-32 hidden widths, a single-layer model, and >2 output
/// classes.
fn shapes() -> Vec<(usize, Vec<usize>)> {
    vec![
        (256, vec![32, 16, 2]),  // the paper's traffic model
        (152, vec![128, 64, 2]), // tomography: odd word count
        (152, vec![33, 7, 3]),   // ragged widths everywhere
        (64, vec![8]),           // single (output-only) layer
        (96, vec![17, 5]),       // 5-class verdicts
    ]
}

#[test]
fn batch_kernel_bit_exact_across_shapes_and_batch_sizes() {
    for (si, (in_bits, arch)) in shapes().into_iter().enumerate() {
        let model = BnnModel::random(&format!("m{si}"), in_bits, &arch, 11 + si as u64);
        let mut kernel = BatchKernel::new(&model);
        for batch in [1usize, 7, 33, 1024] {
            let inputs = batch_inputs(in_bits, batch, 1000 * (si as u64 + 1));
            let (want_scores, want_classes) = reference(&model, &inputs);
            let mut classes = Vec::new();
            kernel.run_batch(&inputs, &mut classes);
            assert_eq!(classes, want_classes, "shape {si} batch {batch} classes");
            let mut scores = Vec::new();
            kernel.infer_batch_scores(&inputs, &mut scores);
            assert_eq!(scores, want_scores, "shape {si} batch {batch} scores");
        }
    }
}

#[test]
fn ragged_final_tile_every_remainder() {
    // Sweep every batch % TILE remainder around one and two tiles.
    let (in_bits, arch) = (152usize, vec![33usize, 7, 3]);
    let model = BnnModel::random("ragged", in_bits, &arch, 99);
    let mut kernel = BatchKernel::new(&model);
    for batch in 1..=2 * TILE + 1 {
        let inputs = batch_inputs(in_bits, batch, 7000 + batch as u64);
        let (_, want) = reference(&model, &inputs);
        let mut got = Vec::new();
        kernel.run_batch(&inputs, &mut got);
        assert_eq!(got, want, "batch {batch}");
    }
}

#[test]
fn sharded_engine_bit_exact_and_ordered() {
    for (si, (in_bits, arch)) in shapes().into_iter().enumerate() {
        let model = BnnModel::random(&format!("s{si}"), in_bits, &arch, 21 + si as u64);
        for shards in [1usize, 2, 3] {
            let mut engine = ShardedEngine::new(&model, shards);
            for batch in [1usize, 7, 33] {
                let inputs = batch_inputs(in_bits, batch, 500 * (si as u64 + 1));
                let (_, want) = reference(&model, &inputs);
                let mut got = Vec::new();
                engine.run_batch(&inputs, &mut got);
                assert_eq!(got, want, "shape {si} shards {shards} batch {batch}");
            }
        }
    }
}

#[test]
fn sharded_engine_large_batch() {
    let model = BnnModel::random("big", 256, &[32, 16, 2], 31);
    let inputs = batch_inputs(256, 1024, 42);
    let (_, want) = reference(&model, &inputs);
    let mut engine = ShardedEngine::new(&model, 4);
    let mut got = Vec::new();
    engine.run_batch(&inputs, &mut got);
    assert_eq!(got, want);
    let st = engine.stats();
    assert_eq!((st.batches, st.items), (1, 1024));
}

#[test]
fn shard_count_exceeding_batch_size() {
    let model = BnnModel::random("tiny", 64, &[8, 2], 9);
    let mut engine = ShardedEngine::new(&model, 8);
    for batch in [1usize, 3, 7] {
        let inputs = batch_inputs(64, batch, 80 + batch as u64);
        let (_, want) = reference(&model, &inputs);
        let mut got = Vec::new();
        engine.run_batch(&inputs, &mut got);
        assert_eq!(got, want, "batch {batch} across 8 shards");
    }
    // Empty batches are a no-op, not a hang.
    let mut got = vec![7usize];
    engine.run_batch(&[], &mut got);
    assert!(got.is_empty());
}

#[test]
#[should_panic(expected = "input width != model in_words")]
fn kernel_rejects_wrong_input_width() {
    let model = BnnModel::random("w", 64, &[8, 2], 1);
    let mut kernel = BatchKernel::new(&model);
    let mut classes = Vec::new();
    // Model wants 2 words; feed 3.
    kernel.run_batch(&[vec![0u32; 3]], &mut classes);
}

#[test]
#[should_panic(expected = "shard worker panicked")]
fn engine_surfaces_worker_panic_instead_of_hanging() {
    let model = BnnModel::random("w", 64, &[8, 2], 1);
    let mut engine = ShardedEngine::new(&model, 2);
    let mut classes = Vec::new();
    engine.run_batch(&[vec![0u32; 3]], &mut classes);
}

#[test]
fn owned_batch_path_matches_borrowed() {
    let model = BnnModel::random("own", 152, &[33, 7, 3], 55);
    let inputs = batch_inputs(152, 37, 321);
    let (_, want) = reference(&model, &inputs);
    let mut engine = ShardedEngine::new(&model, 2);
    let mut got = Vec::new();
    engine.run_batch_owned(inputs, &mut got);
    assert_eq!(got, want);
}

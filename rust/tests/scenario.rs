//! ISSUE 8 acceptance: the three paper use cases (§5) run end-to-end
//! through the one `ServeBuilder` runtime — serial AND pipelined, any
//! backend — each clearing its accuracy floor against a seeded oracle,
//! with bit-identical reruns and pipelined ≡ serial verdict histories;
//! and the admin surface round-trips health, a stats scrape, and a
//! publish+rollback against a live scenario run.

use std::thread;

use n3ic::coordinator::{AdminHandle, AdminRequest, AdminResponse, ShedPolicy};
use n3ic::fattree::N_MONITORED_QUEUES;
use n3ic::net::flow::EvictPolicy;
use n3ic::scenario::{ScenarioConfig, ScenarioRegistry, ScenarioReport};
use n3ic::tomography::{PROBE_PERIOD_100G_NS, PROBE_PERIOD_400G_NS, PROBE_PERIOD_40G_NS};

/// Event count small enough for CI, large enough for every scenario to
/// exercise churn/triggers (for tomography it is probe *rounds*).
fn events_for(name: &str) -> u64 {
    if name == "tomography" {
        160
    } else {
        8_000
    }
}

fn run(name: &str, cfg: &ScenarioConfig) -> ScenarioReport {
    ScenarioRegistry::standard().run(name, cfg).expect(name)
}

#[test]
fn every_scenario_clears_its_floor_serial_and_pipelined() {
    for name in ScenarioRegistry::standard().names() {
        let events = events_for(name);
        let serial = run(name, &ScenarioConfig { events, ..Default::default() });
        assert_eq!(serial.scenario, name);
        assert_eq!(serial.backend, "fpga");
        assert!(serial.score.scored > 0, "{name}: nothing scored");
        assert!(
            serial.passes_floor(),
            "{name}: accuracy {:.3} under floor {:.2}",
            serial.score.accuracy,
            serial.floor
        );
        // No eviction/shedding pressure at these sizes: the service must
        // reproduce the oracle's replay exactly.
        assert!(serial.score.coverage > 0.99, "{name}: coverage {}", serial.score.coverage);
        assert_eq!(serial.score.agreement, 1.0, "{name}: fidelity break");

        // The same seeded scenario, pipelined and batched, is the same
        // run: floor holds and the verdict digest is bit-identical.
        let piped = run(
            name,
            &ScenarioConfig { events, workers: 3, batch: 8, ..Default::default() },
        );
        assert!(piped.passes_floor(), "{name} pipelined");
        assert_eq!(piped.digest(), serial.digest(), "{name}: pipelined ≢ serial");
        assert_eq!(
            piped.service.stats.inferences, serial.service.stats.inferences,
            "{name}: inference counts diverge"
        );
    }
}

#[test]
fn scenario_reruns_are_bit_identical() {
    for name in ScenarioRegistry::standard().names() {
        let cfg = ScenarioConfig { events: events_for(name), seed: 23, ..Default::default() };
        let a = run(name, &cfg);
        let b = run(name, &cfg);
        assert_eq!(a.digest(), b.digest(), "{name}: rerun digest drift");
        assert_eq!(a.score, b.score, "{name}: rerun score drift");
        // A different seed is a different run.  (Tomography is exempt:
        // its flow ids are the synthetic per-round sequence, identical
        // across seeds, so only classes could differ.)
        if name != "tomography" {
            let c = run(
                name,
                &ScenarioConfig { events: events_for(name), seed: 24, ..Default::default() },
            );
            assert_ne!(a.digest(), c.digest(), "{name}: seed ignored");
        }
    }
}

#[test]
fn backends_agree_on_the_same_scenario() {
    // Every backend wraps the same bit-exact executor, so the verdict
    // digest is backend-invariant — including the registry (hot-swap)
    // path the admin surface depends on.
    let events = events_for("traffic");
    let fpga = run("traffic", &ScenarioConfig { events, ..Default::default() });
    for backend in ["host", "registry"] {
        let other = run(
            "traffic",
            &ScenarioConfig { events, backend: backend.into(), ..Default::default() },
        );
        assert_eq!(other.digest(), fpga.digest(), "{backend} ≢ fpga");
        assert!(other.passes_floor(), "{backend}");
    }
}

#[test]
fn anomaly_holds_its_floor_under_eviction_and_shedding() {
    // Overload shape: a 2k-flow churning working set forced through a
    // 512-slot table with a tight admission ceiling.  Coverage drops
    // (evicted flows lose their counts; shed triggers never infer) but
    // detection accuracy on the flows that WERE scored must hold, and
    // the whole degraded run must still be deterministic.
    let cfg = ScenarioConfig {
        events: 12_000,
        flows: 2_000,
        flow_capacity: 512,
        evict: EvictPolicy::Lru,
        shed: Some(ShedPolicy::new(5_000.0, 1_000.0)),
        ..Default::default()
    };
    let rep = run("anomaly", &cfg);
    assert!(rep.service.stats.flow_table.evictions > 0, "no eviction pressure");
    assert!(rep.score.coverage < 1.0, "pressure must cost coverage");
    assert!(rep.score.scored > 0, "degraded run scored nothing");
    assert!(
        rep.passes_floor(),
        "degraded accuracy {:.3} under floor {:.2}",
        rep.score.accuracy,
        rep.floor
    );
    let rerun = run("anomaly", &cfg);
    assert_eq!(rep.digest(), rerun.digest(), "degraded run not deterministic");
    assert_eq!(rep.service.stats.sheds, rerun.service.stats.sheds);
}

#[test]
fn tomography_reports_deadlines_for_all_three_link_speeds() {
    let rep = run("tomography", &ScenarioConfig { events: 160, ..Default::default() });
    let links: Vec<&str> = rep.deadlines.iter().map(|d| d.link).collect();
    assert_eq!(links, vec!["40G", "100G", "400G"]);
    let periods: Vec<f64> = rep.deadlines.iter().map(|d| d.period_ns).collect();
    assert_eq!(
        periods,
        vec![PROBE_PERIOD_40G_NS, PROBE_PERIOD_100G_NS, PROBE_PERIOD_400G_NS]
    );
    for d in &rep.deadlines {
        assert_eq!(d.nns, N_MONITORED_QUEUES, "{}: one NN per monitored queue", d.link);
    }
    // The FPGA module is paper-fast: 17 serialized NNs fit the 250 µs
    // 40G budget with two orders of magnitude to spare.  Tighter links
    // can only be harder — ok must be monotone down the list.
    assert!(rep.deadlines[0].ok, "40G budget missed");
    for w in rep.deadlines.windows(2) {
        assert!(w[0].ok || !w[1].ok, "deadline ok not monotone in link speed");
    }
    // The flow-stats scenarios have no probe deadline story.
    let traffic = run("traffic", &ScenarioConfig { events: 4_000, ..Default::default() });
    assert!(traffic.deadlines.is_empty());
}

#[test]
fn admin_surface_round_trips_against_a_live_scenario() {
    let admin = AdminHandle::new();
    let cfg = ScenarioConfig {
        events: 300_000,
        backend: "registry".into(),
        admin: Some(admin.clone()),
        ..Default::default()
    };
    let server = thread::spawn(move || ScenarioRegistry::standard().run("anomaly", &cfg));

    // Health is answerable before the service even binds; poll until
    // the run has demonstrably ingested packets (it may also already
    // have finished — both are fine, the counters persist).
    let mut saw_packets = 0u64;
    for _ in 0..1_000_000 {
        if let AdminResponse::Health(h) = admin.handle(AdminRequest::Health).unwrap() {
            if h.packets > 0 {
                saw_packets = h.packets;
                break;
            }
        }
        thread::yield_now();
    }
    assert!(saw_packets > 0, "never observed a live packet counter");

    // Capability introspection: the registry backend is bound and
    // hot-swappable (publish/rollback depend on exactly this).
    match admin.handle(AdminRequest::route("GET", "/capabilities").unwrap()).unwrap() {
        AdminResponse::Capabilities(c) => {
            assert_eq!(c.backend, "registry");
            assert!(c.supports_hot_swap);
            assert!(!c.summary().is_empty());
        }
        other => panic!("{other:?}"),
    }

    // Touch-publish the live slot (same weights, new version), then
    // roll it back — versions must be strictly monotone.
    let v_touch = match admin
        .handle(AdminRequest::route("POST", "/models/anomaly/publish").unwrap())
        .unwrap()
    {
        AdminResponse::Published(tag) => tag,
        other => panic!("{other:?}"),
    };
    assert!(v_touch.version() >= 2, "publish at build is v1, touch must be later");
    let v_back = match admin
        .handle(AdminRequest::route("POST", "/models/anomaly/rollback").unwrap())
        .unwrap()
    {
        AdminResponse::RolledBack(tag) => tag,
        other => panic!("{other:?}"),
    };
    assert!(v_back.version() > v_touch.version(), "rollback must bump the version");

    let rep = server.join().unwrap().expect("scenario run");
    assert!(rep.passes_floor());

    // Post-run health: finished cleanly, counter matches the report.
    match admin.handle(AdminRequest::route("GET", "/healthz").unwrap()).unwrap() {
        AdminResponse::Health(h) => {
            assert!(!h.serving && !h.failed);
            assert_eq!(h.packets, rep.service.stats.packets);
        }
        other => panic!("{other:?}"),
    }
    // Final stats scrape is the run's own report.
    match admin.handle(AdminRequest::route("GET", "/stats").unwrap()).unwrap() {
        AdminResponse::Stats(s) => {
            assert_eq!(s.packets, rep.service.stats.packets);
            assert_eq!(s.inferences, rep.service.stats.inferences);
        }
        other => panic!("{other:?}"),
    }

    // The touch/rollback cycle republished identical weights, so the
    // run's verdicts match an admin-free reference run bit for bit.
    let reference = run(
        "anomaly",
        &ScenarioConfig { events: 300_000, backend: "registry".into(), ..Default::default() },
    );
    assert_eq!(rep.digest(), reference.digest(), "admin ops perturbed verdicts");
}

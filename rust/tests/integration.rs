//! Cross-layer integration tests: every executor (host core, FPGA model,
//! PISA interpreter, PJRT artifact) must agree bit-for-bit with the
//! Pallas-kernel goldens exported by the Python build pass, and the
//! end-to-end pipelines must compose.
//!
//! Property-style tests use the crate's deterministic RNG in place of
//! proptest (the build is offline).

use std::path::PathBuf;

use n3ic::bnn::{infer_packed, infer_scores, load_golden, BnnLayer, BnnModel};
use n3ic::coordinator::{
    BackendFactory, OutputSelector, PacketEvent, ServeBuilder, TriggerCondition,
};
use n3ic::net::traffic::{CbrSpec, Rng, TrafficGen};
use n3ic::pisa::compile_bnn;
#[cfg(feature = "pjrt")]
use n3ic::runtime::{Manifest, PjrtRuntime};

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn trained_models() -> Vec<BnnModel> {
    ["traffic", "anomaly", "tomography_32", "tomography_64", "tomography_128"]
        .iter()
        .filter_map(|n| BnnModel::load_named(&artifacts(), n).ok())
        .collect()
}

#[test]
fn goldens_cover_all_trained_models() {
    let models = trained_models();
    if models.is_empty() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    for m in &models {
        let g = load_golden(&artifacts(), &m.name).expect("golden");
        assert_eq!(g.in_words, m.in_words());
        for ((x, scores), class) in g.inputs.iter().zip(&g.scores).zip(&g.classes) {
            assert_eq!(&infer_scores(m, x), scores, "{} core vs pallas", m.name);
            assert_eq!(infer_packed(m, x), *class, "{} argmax", m.name);
        }
    }
}

#[test]
fn pisa_pipeline_agrees_with_goldens() {
    for m in trained_models() {
        let Ok(prog) = compile_bnn(&m) else {
            // tomography_64/128 exceed the PISA budget — expected.
            assert!(m.neurons[0] > 32, "{} should compile", m.name);
            continue;
        };
        let g = load_golden(&artifacts(), &m.name).unwrap();
        for (x, want) in g.inputs.iter().zip(&g.scores) {
            assert_eq!(&prog.run(x), want, "{} pisa vs pallas", m.name);
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_agrees_with_goldens_all_models() {
    if !artifacts().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = PjrtRuntime::new(&artifacts()).unwrap();
    for m in trained_models() {
        let key = Manifest::key_for(&m, 1);
        let g = load_golden(&artifacts(), &m.name).unwrap();
        for (x, want) in g.inputs.iter().zip(&g.scores).take(4) {
            let got = rt.infer_batch(&key, &m, std::slice::from_ref(x)).unwrap();
            assert_eq!(&got[0], want, "{} pjrt vs pallas", m.name);
        }
    }
}

/// Property: for random models and inputs, the PISA pipeline, the FPGA
/// functional path and the core executor are identical.
#[test]
fn property_cross_executor_equality() {
    let mut rng = Rng::new(2024);
    for case in 0..25 {
        let in_bits = [64usize, 128, 152, 256][(rng.below(4)) as usize];
        let n1 = [8usize, 16, 32][(rng.below(3)) as usize];
        let model = BnnModel::random(
            &format!("prop{case}"),
            in_bits,
            &[n1, 8, 2],
            rng.next_u64(),
        );
        let prog = compile_bnn(&model).unwrap();
        let mut fpga = n3ic::fpga::FpgaExecutor::new(model.clone(), 1);
        for _ in 0..4 {
            let x = BnnLayer::random(1, in_bits, rng.next_u64()).words;
            let core = infer_scores(&model, &x);
            assert_eq!(prog.run(&x), core, "case {case}");
            let mut fpga_scores = vec![0i32; 2];
            fpga.infer(&x, &mut fpga_scores);
            assert_eq!(fpga_scores, core, "case {case}");
        }
    }
}

/// Property: flow-statistics features are deterministic and stable under
/// packet reordering of identical packets (same sizes/timestamps set).
#[test]
fn property_feature_determinism() {
    use n3ic::net::features::FeatureVector;
    use n3ic::net::flow::FlowTable;
    let mut gen = TrafficGen::new(CbrSpec { gbps: 10.0, pkt_size: 512 }, 4, 9);
    let pkts: Vec<_> = (0..64).map(|_| gen.next_packet()).collect();
    let run = |pkts: &[n3ic::net::packet::Packet]| {
        let mut t = FlowTable::new(64);
        let mut last = None;
        for p in pkts {
            let up = t.update(p).unwrap();
            last = Some(FeatureVector::from_stats(up.stats).pack());
        }
        last.unwrap()
    };
    assert_eq!(run(&pkts), run(&pkts));
}

/// End to end: the unified service over generated traffic with a
/// trained model classifies every triggered flow and the results match
/// direct inference on the same features.
#[test]
fn e2e_service_with_trained_model() {
    let model = BnnModel::load_named(&artifacts(), "traffic")
        .unwrap_or_else(|_| BnnModel::random("traffic", 256, &[32, 16, 2], 1));
    let events =
        PacketEvent::cbr_burst(CbrSpec { gbps: 40.0, pkt_size: 256 }, 300, 5, 20_000);
    let rep = ServeBuilder::new()
        .backend(BackendFactory::single("fpga", model).unwrap())
        .trigger(TriggerCondition::EveryNPackets(10))
        .output(OutputSelector::Memory)
        .build()
        .unwrap()
        .run(events)
        .unwrap();
    assert!(rep.stats.inferences > 100, "{}", rep.stats.inferences);
    assert_eq!(rep.stats.inferences as usize, rep.sink.memory.len());
    // Class histogram covers only valid classes.
    let total: u64 = rep.stats.classes.iter().sum();
    assert_eq!(total, rep.stats.inferences);
    assert_eq!(rep.stats.classes[2..].iter().sum::<u64>(), 0);
}

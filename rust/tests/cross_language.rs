//! Cross-language layout pinning: the Rust feature packer must produce
//! bit-for-bit the same packed words as `train.binarize.featurize` +
//! `pack_bits` in Python (the training-time view of the same features).
//! Golden produced by `train.export.write_feature_layout_golden`.

use std::path::PathBuf;

use n3ic::json::Json;
use n3ic::net::features::pack_features;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn feature_layout_matches_python() {
    let path = artifacts().join("feature_layout.golden.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let v = Json::parse(&text).unwrap();
    let cases = v.req_array("cases").unwrap();
    assert!(!cases.is_empty());
    for (i, c) in cases.iter().enumerate() {
        let values: Vec<u16> = c
            .req_array("values")
            .unwrap()
            .iter()
            .map(|x| x.as_u64().unwrap() as u16)
            .collect();
        let feature_bits = c.req_usize("feature_bits").unwrap();
        let in_bits = c.req_usize("in_bits").unwrap();
        let want: Vec<u32> = c
            .req_array("packed")
            .unwrap()
            .iter()
            .map(|x| x.as_u64().unwrap() as u32)
            .collect();
        let in_words = n3ic::bnn::words_for(in_bits);
        let got = pack_features(&values, feature_bits, in_words);
        assert_eq!(got, want, "case {i}: rust pack diverged from python");
    }
}

#[test]
fn flow_feature_struct_matches_generic_packer() {
    // FeatureVector::pack (the runtime path) must equal pack_features
    // (the golden-checked path) for 16×16b inputs.
    use n3ic::net::features::FeatureVector;
    let f = FeatureVector([
        0, 1, 0x8000, 0xFFFF, 12345, 54321, 7, 9, 11, 13, 17, 19, 23, 29, 31, 37,
    ]);
    assert_eq!(f.pack().to_vec(), pack_features(&f.0, 16, 8));
}

//! ISSUE 10 acceptance: the online-learning subsystem end-to-end.  The
//! drift scenario's mid-run recipe shift must be detected, retrained
//! away, and republished live — with pipelined runs bit-identical to
//! serial across the swap, the sabotage/force-accept fault injections
//! exercising gate rejection and probation rollback, and the admin
//! surface's `POST /models/<name>/retrain` draining into the learner.

use std::sync::Arc;

use n3ic::bnn::{words_for, BnnLayer, BnnModel, ModelMetrics, RegistryHandle};
use n3ic::coordinator::{
    AdminHandle, AdminRequest, AdminResponse, BackendFactory, ModelRouter, PacketEvent,
    ServeBuilder, TriggerCondition,
};
use n3ic::learn::{min_window_accuracy, recovery_accuracy, GateMode, LearnSpec, LearnStats};
use n3ic::net::features::INPUT_BITS;
use n3ic::net::packet::Packet;
use n3ic::net::traffic::{CbrSpec, ChurnGen, ChurnSpec};
use n3ic::scenario::{ScenarioConfig, ScenarioRegistry, ScenarioReport};

const EVENTS: u64 = 8_000;

fn run_drift(cfg: &ScenarioConfig) -> ScenarioReport {
    ScenarioRegistry::standard().run("drift", cfg).expect("drift scenario")
}

fn learn_stats(rep: &ScenarioReport) -> &LearnStats {
    rep.service.stats.learn.as_ref().expect("drift run must export learn stats")
}

#[test]
fn drift_fires_retrains_and_recovers_end_to_end() {
    let rep = run_drift(&ScenarioConfig { events: EVENTS, ..Default::default() });
    let st = &rep.service.stats;
    let l = learn_stats(&rep);
    let shift_at = EVENTS * 2 / 5;
    assert!(
        l.drift_fired_at.is_some_and(|p| p > shift_at),
        "drift must fire after the recipe shift at {shift_at}: {l:?}"
    );
    assert!(l.retrains >= 1 && l.promotions >= 1, "{l:?}");
    assert!(
        min_window_accuracy(&st.accuracy_timeline) < 0.8,
        "the shift must produce a visible accuracy dip"
    );
    assert!(
        recovery_accuracy(&st.accuracy_timeline, 4) > 0.75,
        "windowed accuracy must recover after the republish"
    );
    assert!(
        rep.passes_floor(),
        "whole-run accuracy {:.3} under floor {:.2}",
        rep.score.accuracy,
        rep.floor
    );
    // No eviction/shedding pressure at this size: the run must match
    // the learner-replay oracle exactly — no dropped or version-mixed
    // verdicts across the live swaps.
    assert!(rep.score.coverage > 0.99, "coverage {}", rep.score.coverage);
    assert_eq!(rep.score.agreement, 1.0, "verdicts diverged from the oracle replay");
}

#[test]
fn pipelined_run_is_bit_identical_across_live_republishes() {
    let serial = run_drift(&ScenarioConfig { events: EVENTS, ..Default::default() });
    let piped = run_drift(&ScenarioConfig {
        events: EVENTS,
        workers: 3,
        batch: 16,
        ..Default::default()
    });
    assert_eq!(
        serial.digest(),
        piped.digest(),
        "pipelined verdicts diverged from serial across a swap"
    );
    assert_eq!(
        serial.service.stats.inferences, piped.service.stats.inferences,
        "inference counts diverged"
    );
    let (a, b) = (learn_stats(&serial), learn_stats(&piped));
    assert_eq!(a.drift_fired_at, b.drift_fired_at, "drift fired at different packets");
    assert_eq!(a.retrains, b.retrains);
    assert_eq!(a.promotions, b.promotions);
    assert_eq!(a.rollbacks, b.rollbacks);
    assert!(piped.passes_floor());
}

#[test]
fn sabotaged_candidates_are_all_rejected_and_nothing_publishes() {
    let rep = run_drift(&ScenarioConfig {
        events: EVENTS,
        gate: Some(GateMode::SabotageCandidate),
        ..Default::default()
    });
    let l = learn_stats(&rep);
    assert!(l.retrains >= 1, "{l:?}");
    assert_eq!(l.promotions, 0, "a sabotaged candidate slipped the gate: {l:?}");
    assert!(l.rejections >= l.retrains, "{l:?}");
    assert_eq!(l.rollbacks, 0, "nothing published, nothing to roll back");
    // The loop never recovers — the floor legitimately fails — but the
    // oracle replays the same sabotage, so fidelity still holds.
    assert!(!rep.passes_floor(), "sabotaged run must stay under the floor");
    assert_eq!(rep.score.agreement, 1.0);
}

#[test]
fn forced_bad_publish_is_rolled_back_then_recovers() {
    let rep = run_drift(&ScenarioConfig {
        events: EVENTS,
        gate: Some(GateMode::ForceAccept),
        ..Default::default()
    });
    let l = learn_stats(&rep);
    assert!(l.rollbacks >= 1, "probation must catch the forced bad model: {l:?}");
    assert!(
        l.promotions >= 2,
        "forced publish plus the honest recovery promotion: {l:?}"
    );
    assert!(
        recovery_accuracy(&rep.service.stats.accuracy_timeline, 4) > 0.75,
        "the loop must still recover after the rollback"
    );
    assert_eq!(rep.score.agreement, 1.0, "rollback path broke oracle fidelity");
}

/// A model whose two neurons share identical weights: tied raw scores,
/// argmax resolves low, every input classifies as class 0.  With an
/// all-benign labeler this serves at accuracy 1.0 — drift can never
/// fire, so any retrain attempt must come from the admin queue.
fn constant_class0_model() -> BnnModel {
    let in_words = words_for(INPUT_BITS);
    let words = vec![0u32; 2 * in_words];
    BnnModel {
        name: "m".into(),
        in_bits: INPUT_BITS,
        neurons: vec![2],
        layers: vec![BnnLayer::new(2, in_words, words).expect("layer dims")],
        metrics: ModelMetrics::default(),
    }
}

#[test]
fn admin_retrain_queue_drains_into_the_learner() {
    let admin = AdminHandle::new();
    // Queue before the run starts: the serving loop drains at its first
    // snapshot tick, so the forced attempt is deterministic, not racy.
    match admin.handle(AdminRequest::route("POST", "/models/m/retrain").unwrap()).unwrap() {
        AdminResponse::RetrainQueued { name } => assert_eq!(name, "m"),
        other => panic!("{other:?}"),
    }
    // A retrain for a slot nobody watches must be ignored, not crash.
    admin
        .handle(AdminRequest::route("POST", "/models/other/retrain").unwrap())
        .unwrap();

    let registry = RegistryHandle::new();
    let model = constant_class0_model();
    registry.publish("m", &model).unwrap();
    let latency_ns = n3ic::fpga::FpgaTiming::new(&model).latency_ns();
    let plane =
        BackendFactory::registry(&registry, &["m".to_string()], latency_ns, 1).unwrap();

    let mut spec = LearnSpec::new("m", Arc::new(|_: &Packet| 0));
    spec.window_pkts = 2_000; // first close already has >32 labeled samples
    spec.holdout = 16;
    spec.train_recent = 64;
    spec.reservoir = 256;

    let trigger = TriggerCondition::EveryNPackets(5);
    let svc = ServeBuilder::new()
        .backend(plane)
        .router(ModelRouter::rules(vec![(trigger, "m".to_string())]))
        .admin(admin.clone())
        .online_learn(spec)
        .build()
        .unwrap();

    let churn = ChurnSpec {
        cbr: CbrSpec { gbps: 40.0, pkt_size: 256 },
        working_set: 64,
        churn_frac: 0.2,
        alpha: 1.2,
        min_pkts: 2,
        max_pkts: 10_000,
    };
    let mut gen = ChurnGen::new(churn, 7);
    let events =
        (0..20_000).map(move |_| PacketEvent { packet: gen.next_packet(), payload_words: None });
    let report = svc.run(events).expect("serve");

    let l = report.stats.learn.as_ref().expect("learn stats");
    assert_eq!(l.retrains, 1, "exactly the one admin-forced attempt: {l:?}");
    // Same-distribution candidate ties the live model on the holdout —
    // it cannot clear the promotion margin, so the gate refuses it.
    assert_eq!(l.rejections, 1, "{l:?}");
    assert_eq!(l.promotions, 0, "{l:?}");
    assert!(l.drift_fired_at.is_none(), "accuracy never dropped: {l:?}");
    assert!(l.windows >= 9, "{l:?}");
    assert!(l.last_window_accuracy > 0.999, "{l:?}");

    // The post-run admin scrape renders the learn series in Prometheus
    // text format — the live observability half of the subsystem.
    match admin.handle(AdminRequest::route("GET", "/metrics").unwrap()).unwrap() {
        AdminResponse::Metrics(text) => {
            assert!(text.contains("n3ic_learn_retrains_total 1"), "{text}");
            assert!(text.contains("n3ic_learn_rejections_total 1"), "{text}");
            assert!(text.contains("n3ic_learn_promotions_total 0"), "{text}");
        }
        other => panic!("{other:?}"),
    }
}

//! Scale + determinism tests for the bounded flow table (ISSUE 7):
//!
//! 1. The paper's headline workload — a million distinct flows through a
//!    table capped far below that — completes, evicts, and is
//!    rerun-identical in serial mode.
//! 2. The pipelined runtime matches the serial runtime verdict-for-
//!    verdict *with eviction active*, across worker counts and both
//!    eviction policies (the [`FLOW_SHARDS`] partition contract).
//! 3. Admission shedding and table eviction fire in the same run
//!    without corrupting the accounting: every trigger is either an
//!    inference or a shed, and evicted-then-returning flows re-trigger
//!    as new flows.

use n3ic::bnn::BnnModel;
use n3ic::coordinator::{
    BackendFactory, OutputSelector, PacketEvent, ServeBuilder, ServiceReport, ShedPolicy,
    TriggerCondition,
};
use n3ic::net::flow::EvictPolicy;
use n3ic::net::traffic::{CbrSpec, ChurnGen, ChurnSpec, TrafficGen};

fn model() -> BnnModel {
    BnnModel::random("traffic", 256, &[32, 16, 2], 1)
}

fn churn_events(working_set: u64, churn_frac: f64, n: usize) -> Vec<PacketEvent> {
    let spec = ChurnSpec {
        churn_frac,
        ..ChurnSpec::adversarial(CbrSpec { gbps: 40.0, pkt_size: 256 }, working_set)
    };
    let mut gen = ChurnGen::new(spec, 11);
    (0..n)
        .map(|_| PacketEvent { packet: gen.next_packet(), payload_words: None })
        .collect()
}

/// The `--flows 1_000_000` acceptance run: 1M-flow population against a
/// table capped at 8192 flows.  The pre-eviction table panicked the
/// moment it filled; this must instead finish, report evictions, and be
/// bit-identical across reruns (serial mode is a pure function of the
/// event stream).
#[test]
fn million_flow_serial_run_completes_and_is_rerun_identical() {
    let run = || -> ServiceReport {
        let mut gen =
            TrafficGen::new(CbrSpec { gbps: 40.0, pkt_size: 256 }, 1_000_000, 7);
        let events = (0..150_000)
            .map(move |_| PacketEvent { packet: gen.next_packet(), payload_words: None });
        ServeBuilder::new()
            .backend(BackendFactory::single("host", model()).unwrap())
            .trigger(TriggerCondition::NewFlow)
            .output(OutputSelector::Memory)
            .flow_capacity(8192)
            .evict(EvictPolicy::Lru)
            .build()
            .unwrap()
            .run(events)
            .unwrap()
    };
    let a = run();
    assert_eq!(a.stats.packets, 150_000);
    let ft = &a.stats.flow_table;
    assert!(ft.evictions > 0, "1M flows into 8192 capacity must evict");
    assert_eq!(ft.untracked, 0, "LRU absorbs every packet");
    // 8192 capacity over 64 shards → 128/shard → 256 slots/shard.
    assert!(a.flows_tracked <= 64 * 256, "tracked={}", a.flows_tracked);
    assert!(a.stats.inferences > 0);
    assert_eq!(a.stats.inferences as usize, a.sink.memory.len());

    let b = run();
    assert_eq!(a.stats.packets, b.stats.packets);
    assert_eq!(a.stats.triggers, b.stats.triggers);
    assert_eq!(a.stats.inferences, b.stats.inferences);
    assert_eq!(a.stats.classes, b.stats.classes);
    assert_eq!(a.stats.flow_table, b.stats.flow_table);
    assert_eq!(a.flows_tracked, b.flows_tracked);
    assert_eq!(a.sink.memory, b.sink.memory, "verdict stream must be bit-identical");
}

/// Determinism contract under eviction: for any worker count, the
/// pipelined runtime's verdict/trigger/eviction counts equal the serial
/// run's on the same churny event stream — because both partition flows
/// into the same [`FLOW_SHARDS`] logical tables and eviction is a pure
/// function of each table's update subsequence.
#[test]
fn pipelined_matches_serial_under_eviction() {
    // 6000-flow working set over ~2048 table slots: constant eviction.
    let events = churn_events(6_000, 0.5, 40_000);
    let policies = [
        ("lru", EvictPolicy::Lru),
        ("age", EvictPolicy::Age { max_idle_ns: 50_000.0 }),
    ];
    for (pname, policy) in policies {
        let run = |workers: usize| -> ServiceReport {
            ServeBuilder::new()
                .backend(BackendFactory::single("host", model()).unwrap())
                .trigger(TriggerCondition::EveryNPackets(3))
                .output(OutputSelector::Memory)
                .flow_capacity(1024)
                .evict(policy)
                .pipeline(workers)
                .build()
                .unwrap()
                .run(events.iter().cloned())
                .unwrap()
        };
        let serial = run(0);
        assert!(serial.stats.triggers > 0, "{pname}: no triggers");
        assert!(
            serial.stats.flow_table.evictions > 0,
            "{pname}: churn must evict"
        );
        let mut serial_verdicts = serial.sink.memory.clone();
        serial_verdicts.sort_unstable();
        for workers in [1usize, 2, 4] {
            let pip = run(workers);
            let tag = format!("{pname}, {workers} workers");
            assert_eq!(serial.stats.packets, pip.stats.packets, "{tag}");
            assert_eq!(serial.stats.triggers, pip.stats.triggers, "{tag}");
            assert_eq!(serial.stats.inferences, pip.stats.inferences, "{tag}");
            assert_eq!(serial.stats.classes, pip.stats.classes, "{tag}");
            // Same logical tables → same evictions/aging/probe walks,
            // merged key-wise across the workers that own them.
            assert_eq!(serial.stats.flow_table, pip.stats.flow_table, "{tag}");
            assert_eq!(serial.flows_tracked, pip.flows_tracked, "{tag}");
            // Verdict *set* is identical; arrival order is scheduling-
            // dependent in the staged runtime.
            let mut pip_verdicts = pip.sink.memory.clone();
            pip_verdicts.sort_unstable();
            assert_eq!(serial_verdicts, pip_verdicts, "{tag}");
        }
    }
}

/// Satellite: overload shedding and table eviction interacting in one
/// run.  A slow modeled backend under churny traffic sheds triggers
/// while the capped table evicts flows — and the books still balance:
/// `triggers == inferences + sheds`.  Without shedding, the same stream
/// shows evicted-then-returning flows re-triggering as brand-new flows.
#[test]
fn shedding_and_eviction_interact_without_losing_accounting() {
    // 20k-flow working set over ~2048 slots; NewFlow trigger at 40Gb/s
    // arrival spacing against 50µs modeled work → admission sheds.
    let events = churn_events(20_000, 0.3, 60_000);
    let run = |shed: bool| -> ServiceReport {
        let mut b = ServeBuilder::new()
            .backend(BackendFactory::custom("slownic", model(), 50_000.0, 1))
            .trigger(TriggerCondition::NewFlow)
            .output(OutputSelector::Memory)
            .flow_capacity(1024)
            .evict(EvictPolicy::Lru);
        if shed {
            b = b.shed(ShedPolicy::new(400_000.0, 100_000.0));
        }
        b.build().unwrap().run(events.iter().cloned()).unwrap()
    };

    let shedded = run(true);
    assert!(shedded.stats.sheds > 0, "50µs work at 18Mpps must shed");
    assert!(shedded.stats.flow_table.evictions > 0, "churn must evict");
    assert_eq!(
        shedded.stats.triggers,
        shedded.stats.inferences + shedded.stats.sheds,
        "every trigger is exactly one of: inference, shed"
    );
    assert_eq!(shedded.stats.inferences as usize, shedded.sink.memory.len());

    let unshedded = run(false);
    assert!(unshedded.stats.flow_table.evictions > 0);
    assert_eq!(unshedded.stats.triggers, unshedded.stats.inferences);
    // Under a NewFlow trigger a flow id can only appear twice in the
    // verdict sink if its entry was evicted and the flow came back —
    // stats reset, `is_new` fired again.  Churn guarantees returners.
    let mut ids: Vec<u64> = unshedded.sink.memory.iter().map(|&(id, _)| id).collect();
    ids.sort_unstable();
    let retriggered = ids.windows(2).filter(|w| w[0] == w[1]).count();
    assert!(
        retriggered > 0,
        "no evicted flow re-triggered as new across {} verdicts",
        ids.len()
    );
}

//! Failure-injection tests: malformed artifacts, missing files, dead
//! pipeline stages, and boundary conditions must fail loudly and
//! precisely (a deployed NIC service cannot limp along with a
//! half-loaded model — or hang on a poisoned stage channel).

use std::path::PathBuf;

use n3ic::bnn::{BnnModel, EngineError, VersionTag};
use n3ic::coordinator::{
    BackendFactory, Capabilities, FaultPlan, InferencePlane, OutputSelector, PacketEvent,
    ServeBuilder, ServiceError, StageFailure, SupervisorPolicy, TriggerCondition,
};
use n3ic::json::Json;
use n3ic::net::traffic::CbrSpec;
#[cfg(feature = "pjrt")]
use n3ic::runtime::PjrtRuntime;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("n3ic_fail_{name}_{}", std::process::id()));
    std::fs::create_dir_all(d.join("models")).unwrap();
    d
}

fn write_model(dir: &std::path::Path, name: &str, body: &str) {
    std::fs::write(dir.join("models").join(format!("{name}.json")), body).unwrap();
}

#[test]
fn missing_model_file_reports_path() {
    let err = BnnModel::load_named(&PathBuf::from("/nonexistent"), "traffic")
        .unwrap_err()
        .to_string();
    assert!(err.contains("/nonexistent"), "{err}");
    assert!(err.contains("traffic.json"), "{err}");
}

#[test]
fn truncated_json_rejected() {
    let d = tmpdir("trunc");
    write_model(&d, "m", r#"{"name":"m","in_bits":64,"neurons":[8,2],"layers":[{"neuro"#);
    assert!(BnnModel::load_named(&d, "m").is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn wrong_weight_count_rejected() {
    let d = tmpdir("badlen");
    // 8-neuron layer over 64 bits needs 16 words; give 15.
    let words: Vec<String> = (0..15).map(|i| i.to_string()).collect();
    write_model(
        &d,
        "m",
        &format!(
            r#"{{"name":"m","in_bits":64,"neurons":[8],
               "layers":[{{"neurons":8,"in_words":2,"threshold":32,
               "words":[{}]}}]}}"#,
            words.join(",")
        ),
    );
    let err = BnnModel::load_named(&d, "m").unwrap_err().to_string();
    assert!(err.contains("weight length"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn corrupted_threshold_rejected() {
    let d = tmpdir("thr");
    let words: Vec<String> = (0..16).map(|_| "0".to_string()).collect();
    write_model(
        &d,
        "m",
        &format!(
            r#"{{"name":"m","in_bits":64,"neurons":[8],
               "layers":[{{"neurons":8,"in_words":2,"threshold":31,
               "words":[{}]}}]}}"#,
            words.join(",")
        ),
    );
    let err = BnnModel::load_named(&d, "m").unwrap_err().to_string();
    assert!(err.contains("threshold"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[cfg(feature = "pjrt")]
#[test]
fn runtime_without_manifest_fails() {
    let d = tmpdir("noman");
    assert!(PjrtRuntime::new(&d).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[cfg(feature = "pjrt")]
#[test]
fn runtime_rejects_unknown_artifact_and_bad_batch() {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        return;
    }
    let mut rt = PjrtRuntime::new(&artifacts).unwrap();
    let model = BnnModel::load_named(&artifacts, "traffic")
        .unwrap_or_else(|_| BnnModel::random("traffic", 256, &[32, 16, 2], 1));
    // Unknown key.
    let x = vec![0u32; model.in_words()];
    let err = rt
        .infer_batch("nope_b1", &model, std::slice::from_ref(&x))
        .unwrap_err()
        .to_string();
    assert!(err.contains("not in manifest"), "{err}");
    // Wrong batch size for a valid artifact.
    let err = rt
        .infer_batch("mlp256_b32", &model, std::slice::from_ref(&x))
        .unwrap_err()
        .to_string();
    assert!(err.contains("batch"), "{err}");
    // Wrong architecture for the artifact.
    let tomo = BnnModel::random("tomo", 152, &[128, 64, 2], 1);
    let xt = vec![0u32; tomo.in_words()];
    let err = rt
        .infer_batch("mlp256_b1", &tomo, std::slice::from_ref(&xt))
        .unwrap_err()
        .to_string();
    assert!(err.contains("mismatch"), "{err}");
}

/// Backend that serves `fuse` inferences and then panics — the
/// injected stage-3 fault for the pipeline tests below, implemented
/// directly against the unified `InferencePlane` trait.
struct DoomedPlane {
    fuse: usize,
}

impl DoomedPlane {
    fn classify_one(&mut self) -> usize {
        if self.fuse == 0 {
            panic!("injected inference fault");
        }
        self.fuse -= 1;
        0
    }
}

impl InferencePlane for DoomedPlane {
    fn capabilities(&self) -> Capabilities {
        Capabilities::single("doomed", 100.0)
    }

    fn classify(&mut self, _route: usize, _x: &[u32]) -> (usize, Option<VersionTag>) {
        (self.classify_one(), None)
    }

    fn try_run_batch(
        &mut self,
        _route: usize,
        inputs: &[Vec<u32>],
        classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, EngineError> {
        classes.clear();
        for _ in inputs {
            let c = self.classify_one();
            classes.push(c);
        }
        Ok(None)
    }

    fn n_classes(&self) -> usize {
        2
    }
}

fn traffic_events(packets: usize, flows: u64, seed: u64) -> Vec<PacketEvent> {
    PacketEvent::cbr_burst(CbrSpec { gbps: 40.0, pkt_size: 256 }, flows, seed, packets)
}

fn doomed(workers: usize, queue_depth: usize, batch: usize) -> ServeBuilder {
    let mut b = ServeBuilder::new()
        .backend(Box::new(DoomedPlane { fuse: 5 }))
        .trigger(TriggerCondition::EveryNPackets(2))
        .output(OutputSelector::Memory)
        .pipeline(workers)
        .queue_depth(queue_depth);
    if batch > 0 {
        b = b.batching(batch, 1e6);
    }
    b
}

#[test]
fn pipeline_stage_death_surfaces_error_with_stats_intact() {
    // Stage 3's backend dies after 5 verdicts.  The poisoned channels
    // must cascade into a clean shutdown — an Err carrying everything
    // accumulated so far — not a hung service.  (This test completing
    // at all *is* the no-hang assertion.)
    //
    // queue_depth 4: with ~200 triggers against a fuse of 5, the parse
    // workers are guaranteed to be in (or attempt) a send on the
    // poisoned link after the fault, whatever the scheduler does — the
    // disconnect observation below is deterministic.
    let events = traffic_events(20_000, 200, 17);
    let err = doomed(2, 4, 0)
        .build()
        .unwrap()
        .run(events)
        .expect_err("a dead stage must not look healthy");
    assert!(err.to_string().contains("injected inference fault"), "{err}");
    let ServiceError::Stage { failures, report } = err else {
        panic!("stage death must surface as ServiceError::Stage");
    };
    // The fault itself is named as a typed panic failure...
    assert!(
        failures
            .iter()
            .any(|f| matches!(f, StageFailure::Panicked { stage: "inference stage", .. })),
        "{failures:?}"
    );
    // ...and the upstream stages report the disconnect rather than
    // dying silently (plenty of triggers remain after the 6th).
    assert!(
        failures
            .iter()
            .any(|f| matches!(f, StageFailure::ParseDisconnected { .. })),
        "{failures:?}"
    );
    // Stats survive the fault: the packets and triggers the parse
    // workers processed, and exactly the verdicts that reached the
    // sink before the fuse blew.
    let st = &report.stats;
    assert!(st.packets > 0);
    assert!(st.triggers >= 6);
    assert_eq!(st.inferences, 5);
    assert_eq!(st.classes.iter().sum::<u64>(), 5);
    assert_eq!(report.sink.memory.len(), 5);
}

#[test]
fn pipeline_stage_death_on_the_batched_route_also_surfaces() {
    let events = traffic_events(20_000, 200, 23);
    let err = doomed(3, 1024, 8)
        .build()
        .unwrap()
        .run(events)
        .expect_err("batched route must surface the fault too");
    let ServiceError::Stage { failures, report } = err else {
        panic!("stage death must surface as ServiceError::Stage");
    };
    assert!(
        failures
            .iter()
            .any(|f| matches!(f, StageFailure::Panicked { .. })),
        "{failures:?}"
    );
    // The fuse blew mid-batch: fewer verdicts than served inferences
    // ever reached the sink, and nothing hung.
    assert!(report.stats.inferences <= 5);
    assert!(report.stats.packets > 0);
}

#[test]
fn serial_engine_fault_is_typed_and_preserves_partial_report() {
    // A sharded backend fed a malformed payload (wrong input width):
    // the shard worker panics, the engine reports it, and the *serial*
    // service absorbs it as a typed `StageFailure::Inference` carrying
    // the partial report — symmetric with the pipelined mode's
    // stage-death semantics instead of the old panic.
    let model = BnnModel::random("traffic", 256, &[32, 16, 2], 1);
    let mut events = traffic_events(4_000, 40, 29);
    // Every packet triggers with its payload as the NN input; packet
    // #100 carries a 3-word payload against a 8-word model.
    for ev in &mut events {
        ev.payload_words = Some(vec![0u32; 8]);
    }
    events[100].payload_words = Some(vec![0u32; 3]);
    let err = ServeBuilder::new()
        .backend(BackendFactory::single_sharded("sharded", model, 2).unwrap())
        .trigger(TriggerCondition::EveryPacket)
        .output(OutputSelector::Memory)
        .batching(4, 1e12)
        .build()
        .unwrap()
        .run(events)
        .expect_err("a poisoned batch must surface as a typed error");
    let ServiceError::Stage { failures, report } = err else {
        panic!("serial engine fault must surface as ServiceError::Stage");
    };
    assert!(
        failures
            .iter()
            .any(|f| matches!(f, StageFailure::Inference(EngineError::WorkerPanicked { .. }))),
        "{failures:?}"
    );
    // Everything before the poisoned batch survives in the report.
    assert_eq!(report.stats.packets, 4_000);
    assert_eq!(report.stats.triggers, 4_000);
    assert_eq!(report.stats.inferences, 100);
    assert_eq!(report.sink.memory.len(), 100);
}

/// Real (fpga) backend behind a 2-worker pipeline with small batches —
/// the configuration the per-stage kill tests below run under load.
fn fpga_pipeline() -> ServeBuilder {
    let model = BnnModel::random("traffic", 256, &[32, 16, 2], 1);
    ServeBuilder::new()
        .backend(BackendFactory::single("fpga", model).unwrap())
        .trigger(TriggerCondition::EveryNPackets(2))
        .output(OutputSelector::Memory)
        .pipeline(2)
        .queue_depth(64)
        .batching(4, 1e6)
}

#[test]
fn supervised_stage_kills_recover_and_match_the_clean_run() {
    let events = traffic_events(20_000, 200, 41);
    let clean = fpga_pipeline().build().unwrap().run(events.iter().cloned()).unwrap();
    assert_eq!(clean.stats.restarts, 0);
    assert!(clean.stats.inferences >= 100, "need real load for the kills below");
    let plans = [
        ("parse", FaultPlan::new().kill_parse_at(500)),
        ("inference", FaultPlan::new().kill_inference_at(10)),
        ("sink", FaultPlan::new().kill_sink_at(50)),
    ];
    for (which, plan) in plans {
        let rep = fpga_pipeline()
            .supervise(SupervisorPolicy::default())
            .inject_faults(plan)
            .build()
            .unwrap()
            .run(events.iter().cloned())
            .unwrap_or_else(|e| panic!("{which}: supervised run must complete: {e}"));
        // The restart is visible in the report, and the recovered run is
        // indistinguishable from the clean one everywhere else — the
        // fault hook fires before the stage's compute, so the retried
        // unit replays exactly once.
        assert!(rep.stats.restarts > 0, "{which}");
        assert_eq!(rep.stats.packets, clean.stats.packets, "{which}");
        assert_eq!(rep.stats.triggers, clean.stats.triggers, "{which}");
        assert_eq!(rep.stats.inferences, clean.stats.inferences, "{which}");
        assert_eq!(rep.stats.classes, clean.stats.classes, "{which}");
        let mut want = clean.sink.memory.clone();
        let mut got = rep.sink.memory.clone();
        want.sort_unstable();
        got.sort_unstable();
        assert_eq!(want, got, "{which}");
    }
}

#[test]
fn unsupervised_stage_kills_fail_loudly_with_consistent_partial_reports() {
    let events = traffic_events(20_000, 200, 43);
    let plans = [
        ("parse worker", FaultPlan::new().kill_parse_at(500)),
        ("inference stage", FaultPlan::new().kill_inference_at(10)),
        ("sink stage", FaultPlan::new().kill_sink_at(50)),
    ];
    for (expect, plan) in plans {
        let err = fpga_pipeline()
            .inject_faults(plan)
            .build()
            .unwrap()
            .run(events.iter().cloned())
            .expect_err("an unsupervised stage kill must surface as an error");
        let ServiceError::Stage { failures, report } = err else {
            panic!("{expect}: stage death must surface as ServiceError::Stage");
        };
        assert!(
            failures.iter().any(|f| matches!(
                f,
                StageFailure::Panicked { stage, message }
                    if *stage == expect && message.contains("injected")
            )),
            "{expect}: {failures:?}"
        );
        // The partial report stays self-consistent: every verdict that
        // reached the sink is accounted exactly once, nothing is
        // double-counted through the panic, and no restart fired.
        assert_eq!(report.stats.restarts, 0, "{expect}");
        assert_eq!(report.stats.inferences as usize, report.sink.memory.len(), "{expect}");
        assert_eq!(
            report.stats.classes.iter().sum::<u64>(),
            report.stats.inferences,
            "{expect}"
        );
        assert!(report.stats.packets > 0, "{expect}");
    }
}

#[test]
fn json_numbers_preserve_u32_exactly() {
    // The weight path must not lose bits through the f64 JSON layer.
    for v in [0u32, 1, 0x7FFF_FFFF, 0x8000_0000, u32::MAX] {
        let j = Json::parse(&format!("[{v}]")).unwrap();
        assert_eq!(j.as_array().unwrap()[0].as_u64().unwrap() as u32, v);
    }
}

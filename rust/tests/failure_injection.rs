//! Failure-injection tests: malformed artifacts, missing files, and
//! boundary conditions must fail loudly and precisely (a deployed NIC
//! service cannot limp along with a half-loaded model).

use std::path::PathBuf;

use n3ic::bnn::BnnModel;
use n3ic::json::Json;
#[cfg(feature = "pjrt")]
use n3ic::runtime::PjrtRuntime;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("n3ic_fail_{name}_{}", std::process::id()));
    std::fs::create_dir_all(d.join("models")).unwrap();
    d
}

fn write_model(dir: &PathBuf, name: &str, body: &str) {
    std::fs::write(dir.join("models").join(format!("{name}.json")), body).unwrap();
}

#[test]
fn missing_model_file_reports_path() {
    let err = BnnModel::load_named(&PathBuf::from("/nonexistent"), "traffic")
        .unwrap_err()
        .to_string();
    assert!(err.contains("/nonexistent"), "{err}");
    assert!(err.contains("traffic.json"), "{err}");
}

#[test]
fn truncated_json_rejected() {
    let d = tmpdir("trunc");
    write_model(&d, "m", r#"{"name":"m","in_bits":64,"neurons":[8,2],"layers":[{"neuro"#);
    assert!(BnnModel::load_named(&d, "m").is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn wrong_weight_count_rejected() {
    let d = tmpdir("badlen");
    // 8-neuron layer over 64 bits needs 16 words; give 15.
    let words: Vec<String> = (0..15).map(|i| i.to_string()).collect();
    write_model(
        &d,
        "m",
        &format!(
            r#"{{"name":"m","in_bits":64,"neurons":[8],
               "layers":[{{"neurons":8,"in_words":2,"threshold":32,
               "words":[{}]}}]}}"#,
            words.join(",")
        ),
    );
    let err = BnnModel::load_named(&d, "m").unwrap_err().to_string();
    assert!(err.contains("weight length"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn corrupted_threshold_rejected() {
    let d = tmpdir("thr");
    let words: Vec<String> = (0..16).map(|_| "0".to_string()).collect();
    write_model(
        &d,
        "m",
        &format!(
            r#"{{"name":"m","in_bits":64,"neurons":[8],
               "layers":[{{"neurons":8,"in_words":2,"threshold":31,
               "words":[{}]}}]}}"#,
            words.join(",")
        ),
    );
    let err = BnnModel::load_named(&d, "m").unwrap_err().to_string();
    assert!(err.contains("threshold"), "{err}");
    std::fs::remove_dir_all(&d).ok();
}

#[cfg(feature = "pjrt")]
#[test]
fn runtime_without_manifest_fails() {
    let d = tmpdir("noman");
    assert!(PjrtRuntime::new(&d).is_err());
    std::fs::remove_dir_all(&d).ok();
}

#[cfg(feature = "pjrt")]
#[test]
fn runtime_rejects_unknown_artifact_and_bad_batch() {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        return;
    }
    let mut rt = PjrtRuntime::new(&artifacts).unwrap();
    let model = BnnModel::load_named(&artifacts, "traffic")
        .unwrap_or_else(|_| BnnModel::random("traffic", 256, &[32, 16, 2], 1));
    // Unknown key.
    let x = vec![0u32; model.in_words()];
    let err = rt
        .infer_batch("nope_b1", &model, std::slice::from_ref(&x))
        .unwrap_err()
        .to_string();
    assert!(err.contains("not in manifest"), "{err}");
    // Wrong batch size for a valid artifact.
    let err = rt
        .infer_batch("mlp256_b32", &model, std::slice::from_ref(&x))
        .unwrap_err()
        .to_string();
    assert!(err.contains("batch"), "{err}");
    // Wrong architecture for the artifact.
    let tomo = BnnModel::random("tomo", 152, &[128, 64, 2], 1);
    let xt = vec![0u32; tomo.in_words()];
    let err = rt
        .infer_batch("mlp256_b1", &tomo, std::slice::from_ref(&xt))
        .unwrap_err()
        .to_string();
    assert!(err.contains("mismatch"), "{err}");
}

#[test]
fn json_numbers_preserve_u32_exactly() {
    // The weight path must not lose bits through the f64 JSON layer.
    for v in [0u32, 1, 0x7FFF_FFFF, 0x8000_0000, u32::MAX] {
        let j = Json::parse(&format!("[{v}]")).unwrap();
        assert_eq!(j.as_array().unwrap()[0].as_u64().unwrap() as u32, v);
    }
}

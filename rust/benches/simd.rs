//! `simd` — GOPS / inputs-per-second grid for the kernel scoring paths
//! (ISSUE 9): the scalar XNOR/popcount loop vs the AVX2 path (when
//! `--features simd` compiled it in and the CPU has it), on the paper's
//! `traffic_32_16_2` model and a deliberately fat fully-connected model
//! where the vector loop has room to win.  A `qmlp` row sizes the
//! fixed-point executor next to them.
//!
//! GOPS counts 2 bit-ops per synapse (XNOR + popcount-accumulate):
//! `work_words × 32 × 2` per inference.  The grid merges into the
//! `benches.simd` entry of `BENCH.json`; `scripts/verify.sh` fails if
//! that key is missing.  Regenerate with:
//!
//! ```text
//! cd rust && cargo bench --bench simd --features simd
//! ```
//!
//! `N3IC_BENCH_SMOKE=1` routes numbers to the gitignored
//! `BENCH.smoke.json`; `N3IC_BENCH_ENFORCE=1` turns the speedup floor
//! (vector ≥ 1.2× scalar on the fat model, only where AVX2 is live)
//! into a nonzero exit code.

use n3ic::bench::{bench, group, smoke_mode, write_bench_json, BenchResult};
use n3ic::bnn::{simd, BatchKernel, BnnLayer, BnnModel, KernelPath};
use n3ic::json::{obj, Json};
use n3ic::qmlp::{QmlpExecutor, QMLP_FRAC_BITS};

const BATCH: usize = 1024;

struct Row {
    model: &'static str,
    path: &'static str,
    lanes: usize,
    batch: usize,
    ns_per_batch: f64,
    inputs_per_sec: f64,
    gops: f64,
}

fn ops_per_inference(model: &BnnModel) -> f64 {
    // XNOR + popcount-accumulate per synapse bit.
    model.work_words() as f64 * 32.0 * 2.0
}

fn inputs_for(model: &BnnModel, batch: usize) -> Vec<Vec<u32>> {
    (0..batch)
        .map(|i| BnnLayer::random(1, model.in_bits, 7_000 + i as u64).words)
        .collect()
}

fn kernel_row(
    rows: &mut Vec<Row>,
    model: &BnnModel,
    model_tag: &'static str,
    path: KernelPath,
    path_tag: &'static str,
) {
    let mut kernel = BatchKernel::new_with_path(model, path);
    let inputs = inputs_for(model, BATCH);
    let mut classes = Vec::with_capacity(BATCH);
    let r: BenchResult = bench(&format!("{model_tag}_{path_tag}_b{BATCH}"), || {
        kernel.run_batch(std::hint::black_box(&inputs), &mut classes);
        classes.len()
    });
    let inputs_per_sec = BATCH as f64 * r.per_second();
    rows.push(Row {
        model: model_tag,
        path: path_tag,
        lanes: kernel.simd_lanes(),
        batch: BATCH,
        ns_per_batch: r.ns_per_iter,
        inputs_per_sec,
        gops: inputs_per_sec * ops_per_inference(model) / 1e9,
    });
}

fn find(rows: &[Row], model: &str, path: &str) -> Option<f64> {
    rows.iter()
        .find(|r| r.model == model && r.path == path)
        .map(|r| r.inputs_per_sec)
}

fn main() {
    println!(
        "simd_compiled={} simd_available={} active_lanes={}",
        simd::simd_compiled(),
        simd::simd_available(),
        simd::active_lanes(),
    );

    let traffic = BnnModel::random("traffic_32_16_2", 256, &[32, 16, 2], 1);
    let fat = BnnModel::random("fc_2048_256_2", 2048, &[256, 2], 2);
    let mut rows: Vec<Row> = Vec::new();

    group("simd / traffic_32_16_2 (the paper's use-case shape)");
    kernel_row(&mut rows, &traffic, "traffic_32_16_2", KernelPath::Scalar, "scalar");
    kernel_row(&mut rows, &traffic, "traffic_32_16_2", KernelPath::Simd, "simd");

    group("simd / fc_2048_256_2 (fat rows: vector headroom)");
    kernel_row(&mut rows, &fat, "fc_2048_256_2", KernelPath::Scalar, "scalar");
    kernel_row(&mut rows, &fat, "fc_2048_256_2", KernelPath::Simd, "simd");

    group("simd / qmlp fixed-point executor (serial, for scale)");
    {
        let mut exec = QmlpExecutor::from_bnn(&traffic, QMLP_FRAC_BITS).unwrap();
        let inputs = inputs_for(&traffic, 64);
        let r = bench("qmlp_traffic_serial", || {
            let mut acc = 0usize;
            for x in &inputs {
                acc += exec.classify(std::hint::black_box(x));
            }
            acc
        });
        let inputs_per_sec = 64.0 * r.per_second();
        rows.push(Row {
            model: "traffic_32_16_2",
            path: "qmlp",
            lanes: 1,
            batch: 64,
            ns_per_batch: r.ns_per_iter,
            inputs_per_sec,
            gops: inputs_per_sec * ops_per_inference(&traffic) / 1e9,
        });
    }

    println!("\n== simd summary ==");
    let enforce = std::env::var_os("N3IC_BENCH_ENFORCE").is_some();
    let mut floors_missed = false;
    if let (Some(scalar), Some(vector)) = (
        find(&rows, "fc_2048_256_2", "scalar"),
        find(&rows, "fc_2048_256_2", "simd"),
    ) {
        let ratio = vector / scalar;
        if simd::simd_available() {
            // The vector path must pay for itself where it runs at all.
            floors_missed |= ratio < 1.2;
            println!(
                "avx2 @ fc_2048_256_2      : {:.2}M inputs/s = {ratio:.2}x scalar \
                 (acceptance floor: 1.2x)",
                vector / 1e6
            );
        } else {
            println!(
                "avx2 unavailable: both rows took the scalar path ({ratio:.2}x, no floor)"
            );
        }
    }
    for r in &rows {
        println!(
            "{:>16} {:>7}  lanes={} batch={:>5}  {:>10.2}M inputs/s  {:>8.2} GOPS",
            r.model,
            r.path,
            r.lanes,
            r.batch,
            r.inputs_per_sec / 1e6,
            r.gops
        );
    }

    let fragment = obj(vec![
        ("smoke", Json::Bool(smoke_mode())),
        ("simd_compiled", Json::Bool(simd::simd_compiled())),
        ("simd_available", Json::Bool(simd::simd_available())),
        ("active_lanes", Json::Num(simd::active_lanes() as f64)),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        obj(vec![
                            ("model", Json::Str(r.model.into())),
                            ("path", Json::Str(r.path.into())),
                            ("lanes", Json::Num(r.lanes as f64)),
                            ("batch", Json::Num(r.batch as f64)),
                            ("ns_per_batch", Json::Num((r.ns_per_batch * 10.0).round() / 10.0)),
                            ("inputs_per_sec", Json::Num(r.inputs_per_sec.round())),
                            ("gops", Json::Num((r.gops * 100.0).round() / 100.0)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match write_bench_json("simd", fragment) {
        Ok(path) => println!("\nmerged into {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench json: {e}"),
    }

    if enforce && floors_missed {
        eprintln!("simd: acceptance floor missed (see summary above)");
        std::process::exit(1);
    }
}

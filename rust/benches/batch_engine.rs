//! `batch_engine` — the throughput acceptance grid for the batched
//! inference subsystem: serial per-item loop (the pre-batch-kernel
//! host path) vs the weight-stationary tiled
//! [`BatchKernel`] vs the [`ShardedEngine`], on the paper's
//! `traffic_32_16_2` model at batch 1/32/1024 × 1/2/4 shards.
//!
//! Besides the human-readable table it merges its grid into the
//! `benches.batch_engine` entry of `BENCH.json` at the repo root so the
//! perf trajectory is machine-trackable PR over PR.  Regenerate with:
//!
//! ```text
//! cd rust && cargo bench --bench batch_engine
//! ```
//!
//! `N3IC_BENCH_SMOKE=1` gives a quick CI pass (written to
//! `BENCH.smoke.json` so noisy numbers never clobber the tracked file);
//! `N3IC_BENCH_ENFORCE=1` turns missed acceptance floors into a nonzero
//! exit code.

use n3ic::bench::{bench, group, smoke_mode, write_bench_json, BenchResult};
use n3ic::bnn::{argmax, BatchKernel, BnnExecutor, BnnLayer, BnnModel, ShardedEngine};
use n3ic::json::{obj, Json};

const MODEL_NAME: &str = "traffic_32_16_2";
const BATCHES: [usize; 3] = [1, 32, 1024];
const SHARDS: [usize; 3] = [1, 2, 4];

struct Row {
    kind: &'static str,
    batch: usize,
    shards: usize,
    ns_per_batch: f64,
    flows_per_sec: f64,
}

fn push_row(rows: &mut Vec<Row>, kind: &'static str, batch: usize, shards: usize, r: &BenchResult) {
    rows.push(Row {
        kind,
        batch,
        shards,
        ns_per_batch: r.ns_per_iter,
        flows_per_sec: batch as f64 * r.per_second(),
    });
}

fn inputs_for(batch: usize) -> Vec<Vec<u32>> {
    (0..batch)
        .map(|i| BnnLayer::random(1, 256, 9000 + i as u64).words)
        .collect()
}

fn find(rows: &[Row], kind: &str, batch: usize, shards: usize) -> Option<f64> {
    rows.iter()
        .find(|r| r.kind == kind && r.batch == batch && r.shards == shards)
        .map(|r| r.flows_per_sec)
}

fn main() {
    let model = BnnModel::random(MODEL_NAME, 256, &[32, 16, 2], 1);
    let mut rows: Vec<Row> = Vec::new();

    group("batch_engine / serial (per-item loop, the pre-kernel baseline)");
    for batch in BATCHES {
        let inputs = inputs_for(batch);
        let mut exec = BnnExecutor::new(model.clone());
        let mut scores = vec![0i32; model.out_neurons()];
        let mut classes: Vec<usize> = Vec::with_capacity(batch);
        let r = bench(&format!("serial_b{batch}"), || {
            classes.clear();
            for x in &inputs {
                exec.infer(std::hint::black_box(x), &mut scores);
                classes.push(argmax(&scores));
            }
            classes.len()
        });
        push_row(&mut rows, "serial", batch, 1, &r);
    }

    group("batch_engine / tiled (weight-stationary kernel, single core)");
    for batch in BATCHES {
        let inputs = inputs_for(batch);
        let mut kernel = BatchKernel::new(&model);
        let mut classes: Vec<usize> = Vec::with_capacity(batch);
        let r = bench(&format!("tiled_b{batch}"), || {
            kernel.run_batch(std::hint::black_box(&inputs), &mut classes);
            classes.len()
        });
        push_row(&mut rows, "tiled", batch, 1, &r);
    }

    group("batch_engine / sharded (tiled kernel × worker threads)");
    for shards in SHARDS {
        for batch in BATCHES {
            // Shared handle built once: the timed loop pays one Arc
            // clone per shard, not a deep copy of the batch (which
            // serial/tiled rows don't pay either).
            let inputs = std::sync::Arc::new(inputs_for(batch));
            let mut engine = ShardedEngine::new(&model, shards);
            let mut classes: Vec<usize> = Vec::with_capacity(batch);
            let r = bench(&format!("sharded_s{shards}_b{batch}"), || {
                engine.run_batch_shared(std::hint::black_box(&inputs), &mut classes);
                classes.len()
            });
            push_row(&mut rows, "sharded", batch, shards, &r);
        }
    }

    println!("\n== batch_engine summary ==");
    // With N3IC_BENCH_ENFORCE set, missed floors fail the process (the
    // machine-checked form of the acceptance criteria).  Off by default:
    // smoke-mode numbers are too noisy to gate on.
    let enforce = std::env::var_os("N3IC_BENCH_ENFORCE").is_some();
    let mut floors_missed = false;
    if let (Some(serial), Some(tiled)) = (
        find(&rows, "serial", 1024, 1),
        find(&rows, "tiled", 1024, 1),
    ) {
        let ratio = tiled / serial;
        floors_missed |= ratio < 2.0;
        println!(
            "tiled kernel @ batch 1024 : {:.2}M flows/s = {ratio:.2}x the serial loop \
             (acceptance floor: 2x)",
            tiled / 1e6
        );
    }
    if let (Some(s1), Some(s4)) = (
        find(&rows, "sharded", 1024, 1),
        find(&rows, "sharded", 1024, 4),
    ) {
        let ratio = s4 / s1;
        // Only meaningful where 4 workers have >1 core to land on.
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        floors_missed |= cores > 1 && ratio < 1.5;
        println!(
            "4 shards  @ batch 1024    : {:.2}M flows/s = {ratio:.2}x one shard \
             (acceptance floor on multi-core hosts: 1.5x; {cores} cores here)",
            s4 / 1e6
        );
    }

    // Smoke numbers are noise: write_bench_json routes them to the
    // gitignored BENCH.smoke.json instead of the tracked perf record.
    let fragment = obj(vec![
        ("model", Json::Str(MODEL_NAME.into())),
        ("smoke", Json::Bool(smoke_mode())),
        (
            "threads_available",
            Json::Num(std::thread::available_parallelism().map_or(1, |n| n.get()) as f64),
        ),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        obj(vec![
                            ("kind", Json::Str(r.kind.into())),
                            ("batch", Json::Num(r.batch as f64)),
                            ("shards", Json::Num(r.shards as f64)),
                            ("ns_per_batch", Json::Num((r.ns_per_batch * 10.0).round() / 10.0)),
                            ("flows_per_sec", Json::Num(r.flows_per_sec.round())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match write_bench_json("batch_engine", fragment) {
        Ok(path) => println!("\nmerged into {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench json: {e}"),
    }

    if enforce && floors_missed {
        eprintln!("batch_engine: acceptance floor missed (see summary above)");
        std::process::exit(1);
    }
}

//! `scenario` — end-to-end throughput of the three paper use cases
//! (§5) through the unified service, serial and pipelined.
//!
//! Each cell runs one seeded scenario end-to-end — workload generation,
//! centroid calibration, oracle replay, and the serve loop all inside
//! the timed region, so `events_per_sec` is the whole use-case cost,
//! not just the hot loop.  Rows land in the `benches.scenario` entry of
//! `BENCH.json`:
//!
//! ```text
//! cd rust && cargo bench --bench scenario
//! ```
//!
//! `N3IC_BENCH_SMOKE=1` shrinks every cell for CI; verify.sh runs that
//! mode and asserts the `"scenario"` key exists.

use std::time::Instant;

use n3ic::bench::{group, smoke_mode, write_bench_json};
use n3ic::json::{obj, Json};
use n3ic::scenario::{ScenarioConfig, ScenarioRegistry};

struct Cell {
    scenario: &'static str,
    events: u64,
    workers: usize,
    batch: usize,
}

fn main() {
    let registry = ScenarioRegistry::standard();
    let scale: u64 = if smoke_mode() { 1 } else { 10 };
    let mut cells = Vec::new();
    for name in registry.names() {
        // Tomography events are probe rounds (each one simulator
        // interval), not packets — keep them two orders smaller.
        let events = if name == "tomography" { 160 * scale } else { 20_000 * scale };
        cells.push(Cell { scenario: name, events, workers: 0, batch: 0 });
        cells.push(Cell { scenario: name, events, workers: 3, batch: 16 });
    }

    group(&format!(
        "scenario / paper use cases ({} mode, {} cells)",
        if smoke_mode() { "smoke" } else { "full" },
        cells.len()
    ));
    let mut rows = Vec::new();
    for cell in &cells {
        let cfg = ScenarioConfig {
            events: cell.events,
            workers: cell.workers,
            batch: cell.batch,
            ..Default::default()
        };
        let t0 = Instant::now();
        let rep = registry.run(cell.scenario, &cfg).expect(cell.scenario);
        let wall_s = t0.elapsed().as_secs_f64();
        let st = &rep.service.stats;
        let eps = st.packets as f64 / wall_s.max(1e-9);
        assert!(
            rep.passes_floor(),
            "{}: bench run under its accuracy floor ({:.3} < {:.2})",
            cell.scenario,
            rep.score.accuracy,
            rep.floor
        );
        println!(
            "{:10} workers={} batch={:>2}  {:>10.0} events/s  inferences={:>7}  acc={:.3} cov={:.3}",
            cell.scenario,
            cell.workers,
            cell.batch,
            eps,
            st.inferences,
            rep.score.accuracy,
            rep.score.coverage,
        );
        let round3 = |v: f64| (v * 1000.0).round() / 1000.0;
        rows.push(obj(vec![
            ("scenario", Json::Str(cell.scenario.to_string())),
            ("backend", Json::Str(rep.backend.to_string())),
            ("workers", Json::Num(cell.workers as f64)),
            ("batch", Json::Num(cell.batch as f64)),
            ("events", Json::Num(st.packets as f64)),
            ("events_per_sec", Json::Num(eps.round())),
            ("inferences", Json::Num(st.inferences as f64)),
            ("accuracy", Json::Num(round3(rep.score.accuracy))),
            ("coverage", Json::Num(round3(rep.score.coverage))),
            ("floor", Json::Num(rep.floor)),
        ]));
    }

    let fragment = obj(vec![
        ("smoke", Json::Bool(smoke_mode())),
        ("rows", Json::Arr(rows)),
    ]);
    match write_bench_json("scenario", fragment) {
        Ok(path) => println!("\nmerged into {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench json: {e}"),
    }
}

//! Benches for the motivation-section substrate (Figs. 3–6): the real
//! packet path — parse, flow-table update, feature extraction/packing.

use n3ic::bench::{bench, group};
use n3ic::net::features::FeatureVector;
use n3ic::net::flow::FlowTable;
use n3ic::net::packet::{parse, Packet, Proto};
use n3ic::net::traffic::{CbrSpec, TrafficGen};

fn main() {
    group("packet path");
    let p = Packet {
        ts_ns: 0.0,
        src_ip: 0x0A000001,
        dst_ip: 0x0B000002,
        src_port: 3333,
        dst_port: 443,
        proto: Proto::Tcp,
        size: 256,
        tcp_flags: 0x18,
    };
    let wire = p.to_wire();
    bench("packet_parse", || parse(std::hint::black_box(&wire)));

    // Fig. 13 baseline work: per-packet lookup + counter update.
    let mut gen = TrafficGen::new(
        CbrSpec {
            gbps: 40.0,
            pkt_size: 256,
        },
        100_000,
        1,
    );
    let pkts: Vec<Packet> = (0..8192).map(|_| gen.next_packet()).collect();
    let mut table = FlowTable::new(1 << 18);
    let mut i = 0usize;
    let r = bench("flow_table_update", || {
        let c = table
            .update(std::hint::black_box(&pkts[i & 8191]))
            .map_or(0, |u| u.pkts);
        i += 1;
        c
    });
    println!(
        "  -> {:.1}M pkt/s flow-stat path on one host core (NFP needs 18.1M across 90 threads)",
        r.per_second() / 1e6
    );

    let mut t = FlowTable::new(64);
    let mut gen = TrafficGen::new(
        CbrSpec {
            gbps: 10.0,
            pkt_size: 512,
        },
        1,
        2,
    );
    let mut stats = Default::default();
    for _ in 0..50 {
        let p = gen.next_packet();
        stats = t.update(&p).unwrap().stats.clone();
    }
    bench("feature_extract_pack", || {
        FeatureVector::from_stats(std::hint::black_box(&stats)).pack()
    });
}

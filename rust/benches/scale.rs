//! `scale` — the paper's headline workload at scale: serving under
//! million-flow adversarial churn with a flow table capped well below
//! the live flow count, so eviction runs continuously instead of never.
//!
//! Each grid cell drives one closed-loop serve run ([`ChurnGen`]
//! traffic, `NewFlow` trigger, host executor) and reports:
//!
//! * sustained packets/s end-to-end (generation + flow table +
//!   trigger + inference + sink),
//! * modeled device latency p50/p99/p999 from the service's
//!   [`LatencyHistogram`](n3ic::metrics::LatencyHistogram),
//! * eviction pressure (evictions + aged_out per million packets) and
//!   final table load factor.
//!
//! Modes:
//!
//! * default           — full grid: 1M / 4M / 16M live flows × {lru,
//!                       age} against a 64Ki-slot-capacity table.
//! * `N3IC_SCALE_GRID=ci` — one bounded 1M-flow cell (the acceptance
//!                       cell verify.sh records into tracked BENCH.json).
//! * `N3IC_BENCH_SMOKE=1` — tiny cells, writes BENCH.smoke.json.
//!
//! Results merge into the `benches.scale` entry of `BENCH.json`:
//!
//! ```text
//! cd rust && cargo bench --bench scale
//! ```

use std::time::Instant;

use n3ic::bench::{group, smoke_mode, write_bench_json};
use n3ic::bnn::BnnModel;
use n3ic::coordinator::{
    BackendFactory, OutputSelector, PacketEvent, ServeBuilder, ServiceReport, TriggerCondition,
};
use n3ic::json::{obj, Json};
use n3ic::net::flow::EvictPolicy;
use n3ic::net::traffic::{CbrSpec, ChurnGen, ChurnSpec};

fn model() -> BnnModel {
    BnnModel::random("traffic", 256, &[32, 16, 2], 1)
}

struct Cell {
    flows: u64,
    packets: usize,
    cap: usize,
    policy: EvictPolicy,
    policy_name: &'static str,
}

/// One serve run over freshly generated churn traffic; wall time spans
/// the whole closed loop so pps is end-to-end, not table-only.
fn run_cell(cell: &Cell) -> (ServiceReport, f64) {
    let svc = ServeBuilder::new()
        .backend(BackendFactory::single("host", model()).unwrap())
        .trigger(TriggerCondition::NewFlow)
        .output(OutputSelector::Memory)
        .flow_capacity(cell.cap)
        .evict(cell.policy)
        .build()
        .unwrap();
    let mut gen = ChurnGen::new(
        ChurnSpec::adversarial(CbrSpec { gbps: 40.0, pkt_size: 256 }, cell.flows),
        7,
    );
    let packets = cell.packets;
    let events = (0..packets).map(move |_| PacketEvent {
        packet: gen.next_packet(),
        payload_words: None,
    });
    let t0 = Instant::now();
    let report = svc.run(events).unwrap();
    (report, t0.elapsed().as_secs_f64())
}

fn main() {
    let ci_grid = std::env::var_os("N3IC_SCALE_GRID")
        .map(|v| v == "ci")
        .unwrap_or(false);
    let (mode, cells): (&str, Vec<Cell>) = if smoke_mode() {
        (
            "smoke",
            vec![Cell {
                flows: 50_000,
                packets: 60_000,
                cap: 4_096,
                policy: EvictPolicy::Lru,
                policy_name: "lru",
            }],
        )
    } else if ci_grid {
        (
            "ci",
            vec![Cell {
                flows: 1_000_000,
                packets: 400_000,
                cap: 32_768,
                policy: EvictPolicy::Lru,
                policy_name: "lru",
            }],
        )
    } else {
        let mut cells = Vec::new();
        for flows in [1_000_000u64, 4_000_000, 16_000_000] {
            for (policy, policy_name) in [
                (EvictPolicy::Lru, "lru"),
                (EvictPolicy::Age { max_idle_ns: 200_000.0 }, "age"),
            ] {
                cells.push(Cell {
                    flows,
                    packets: 2_000_000,
                    cap: 65_536,
                    policy,
                    policy_name,
                });
            }
        }
        ("full", cells)
    };

    group(&format!("scale / churn grid ({mode} mode, {} cells)", cells.len()));
    let mut rows = Vec::new();
    for cell in &cells {
        let (report, wall_s) = run_cell(cell);
        let st = &report.stats;
        let ft = &st.flow_table;
        let pps = cell.packets as f64 / wall_s.max(1e-9);
        let mpkts = cell.packets as f64 / 1e6;
        // Every cell caps the table below the live flow count, so a
        // zero eviction count means the bounded table stopped working.
        assert!(
            ft.evictions + ft.aged_out > 0,
            "cap {} < {} live flows but nothing was evicted",
            cell.cap,
            cell.flows
        );
        println!(
            "flows={:>9} cap={:>6} evict={:<4} {:>10.0} pps  p50={:>8.2}us p99={:>8.2}us p999={:>8.2}us  evictions={} aged_out={} load={:.3}",
            cell.flows,
            cell.cap,
            cell.policy_name,
            pps,
            st.latency.p50_us(),
            st.latency.p99_us(),
            st.latency.p999_us(),
            ft.evictions,
            ft.aged_out,
            ft.load_factor(),
        );
        let round2 = |v: f64| (v * 100.0).round() / 100.0;
        rows.push(obj(vec![
            ("flows", Json::Num(cell.flows as f64)),
            ("packets", Json::Num(cell.packets as f64)),
            ("table_cap", Json::Num(cell.cap as f64)),
            ("evict", Json::Str(cell.policy_name.to_string())),
            ("sustained_pps", Json::Num(pps.round())),
            ("p50_us", Json::Num(round2(st.latency.p50_us()))),
            ("p99_us", Json::Num(round2(st.latency.p99_us()))),
            ("p999_us", Json::Num(round2(st.latency.p999_us()))),
            ("triggers", Json::Num(st.triggers as f64)),
            ("inferences", Json::Num(st.inferences as f64)),
            ("evictions", Json::Num(ft.evictions as f64)),
            ("aged_out", Json::Num(ft.aged_out as f64)),
            (
                "evictions_per_mpkt",
                Json::Num(((ft.evictions + ft.aged_out) as f64 / mpkts).round()),
            ),
            ("flows_tracked", Json::Num(report.flows_tracked as f64)),
            ("load_factor", Json::Num(round2(ft.load_factor()))),
        ]));
    }

    let fragment = obj(vec![
        ("smoke", Json::Bool(smoke_mode())),
        ("mode", Json::Str(mode.to_string())),
        ("rows", Json::Arr(rows)),
    ]);
    match write_bench_json("scale", fragment) {
        Ok(path) => println!("\nmerged into {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench json: {e}"),
    }
}

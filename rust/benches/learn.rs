//! `learn` — cost of the online-learning subsystem (ISSUE 10): how long
//! an in-process retrain takes, and what the drift scenario's
//! detect→retrain→republish loop costs end-to-end, serial and
//! pipelined.
//!
//! Two cell families land in the `benches.learn` entry of `BENCH.json`:
//!
//! * `refit` — wall time of [`n3ic::learn::refit`] on a seeded labeled
//!   sample set, with and without STE fine-tune epochs.  This is the
//!   budget the serving loop pays inline at a window close, so it must
//!   stay far under a window's worth of packet time.
//! * `serve` — the full `drift` scenario (generation, calibration,
//!   oracle replay, serve loop with live republishes) in events/s, with
//!   the learn counters alongside so a run that never retrained can't
//!   masquerade as a fast one.
//!
//! ```text
//! cd rust && cargo bench --bench learn
//! ```
//!
//! `N3IC_BENCH_SMOKE=1` shrinks every cell for CI; verify.sh runs that
//! mode and asserts the `"learn"` key exists.

use std::time::Instant;

use n3ic::bench::{group, smoke_mode, write_bench_json};
use n3ic::bnn::BnnLayer;
use n3ic::json::{obj, Json};
use n3ic::learn::{refit, Sample};
use n3ic::net::features::INPUT_BITS;
use n3ic::scenario::{ScenarioConfig, ScenarioRegistry};

/// Seeded labeled corpus: random packed inputs, labeled by popcount
/// majority — a rule a centroid fit genuinely has to learn.
fn corpus(n: usize, seed: u64) -> Vec<Sample> {
    (0..n)
        .map(|i| {
            let packed = BnnLayer::random(1, INPUT_BITS, seed + i as u64).words;
            let ones: u32 = packed.iter().map(|w| w.count_ones()).sum();
            Sample { packed, label: usize::from(ones as usize * 2 > INPUT_BITS) }
        })
        .collect()
}

fn main() {
    let smoke = smoke_mode();
    group(&format!("learn / retrain + swap-under-load ({} mode)", if smoke { "smoke" } else { "full" }));

    // --- refit latency -------------------------------------------------
    let iters = if smoke { 5 } else { 50 };
    let samples = corpus(512, 42);
    let refs: Vec<&Sample> = samples.iter().collect();
    let mut refit_rows = Vec::new();
    for ste_epochs in [0u32, 2] {
        let t0 = Instant::now();
        let mut out_words = 0usize;
        for i in 0..iters {
            let m = refit("drift", INPUT_BITS, &refs, ste_epochs, 7 + i as u64);
            out_words += m.layers[0].words.len();
        }
        let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
        assert!(out_words > 0);
        println!(
            "refit      samples=512 ste_epochs={}  {:>10.0} ns/refit",
            ste_epochs, ns
        );
        refit_rows.push(obj(vec![
            ("samples", Json::Num(512.0)),
            ("ste_epochs", Json::Num(ste_epochs as f64)),
            ("ns_per_refit", Json::Num(ns.round())),
        ]));
    }

    // --- drift scenario end-to-end ------------------------------------
    let events: u64 = if smoke { 8_000 } else { 16_000 };
    let registry = ScenarioRegistry::standard();
    let mut serve_rows = Vec::new();
    for (workers, batch) in [(0usize, 0usize), (3, 16)] {
        let cfg = ScenarioConfig { events, workers, batch, ..Default::default() };
        let t0 = Instant::now();
        let rep = registry.run("drift", &cfg).expect("drift scenario");
        let wall_s = t0.elapsed().as_secs_f64();
        let st = &rep.service.stats;
        let l = st.learn.as_ref().expect("drift exports learn stats");
        let eps = st.packets as f64 / wall_s.max(1e-9);
        assert!(
            rep.passes_floor(),
            "drift bench run under its accuracy floor ({:.3} < {:.2})",
            rep.score.accuracy,
            rep.floor
        );
        assert!(l.promotions >= 1, "a learn bench run that never republished is meaningless");
        println!(
            "drift      workers={} batch={:>2}  {:>10.0} events/s  retrains={} promotions={} rollbacks={} acc={:.3}",
            workers, batch, eps, l.retrains, l.promotions, l.rollbacks, rep.score.accuracy,
        );
        let round3 = |v: f64| (v * 1000.0).round() / 1000.0;
        serve_rows.push(obj(vec![
            ("workers", Json::Num(workers as f64)),
            ("batch", Json::Num(batch as f64)),
            ("events", Json::Num(st.packets as f64)),
            ("events_per_sec", Json::Num(eps.round())),
            ("retrains", Json::Num(l.retrains as f64)),
            ("promotions", Json::Num(l.promotions as f64)),
            ("rollbacks", Json::Num(l.rollbacks as f64)),
            ("accuracy", Json::Num(round3(rep.score.accuracy))),
        ]));
    }

    let fragment = obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("refit", Json::Arr(refit_rows)),
        ("serve", Json::Arr(serve_rows)),
    ]);
    match write_bench_json("learn", fragment) {
        Ok(path) => println!("\nmerged into {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench json: {e}"),
    }
}

//! `pipeline` — end-to-end throughput grid for the staged serving
//! runtime: the unified `Service` in serial mode vs its pipelined mode
//! at 1/2/4 parse workers × inline/batched inference, on the paper's
//! `traffic_32_16_2` model over seeded 40Gb/s CBR traffic.
//!
//! Before timing anything it **asserts the determinism contract** —
//! every pipelined configuration must reproduce the serial loop's
//! verdict histogram and trigger/inference counts bit for bit — so a
//! `N3IC_BENCH_SMOKE=1` run (scripts/verify.sh) doubles as the CI
//! pipeline-equivalence gate.
//!
//! Results merge into the `benches.pipeline` entry of `BENCH.json`
//! (`BENCH.smoke.json` for smoke runs):
//!
//! ```text
//! cd rust && cargo bench --bench pipeline
//! ```

use n3ic::bench::{bench, group, smoke_mode, write_bench_json};
use n3ic::bnn::BnnModel;
use n3ic::coordinator::{
    BackendFactory, OutputSelector, PacketEvent, ServeBuilder, ServiceReport, TriggerCondition,
    STAGE_LINKS,
};
use n3ic::json::{obj, Json};
use n3ic::net::traffic::CbrSpec;

const MODEL_NAME: &str = "traffic_32_16_2";
const WORKERS: [usize; 3] = [1, 2, 4];
const BATCHES: [usize; 2] = [0, 32];
const TRIGGER: TriggerCondition = TriggerCondition::EveryNPackets(10);

struct Row {
    mode: &'static str,
    workers: usize,
    batch: usize,
    ns_per_pkt: f64,
    mpkts_per_sec: f64,
    blocked: Vec<u64>,
}

fn model() -> BnnModel {
    BnnModel::random(MODEL_NAME, 256, &[32, 16, 2], 1)
}

fn events(packets: usize) -> Vec<PacketEvent> {
    PacketEvent::cbr_burst(CbrSpec { gbps: 40.0, pkt_size: 256 }, 2000, 7, packets)
}

/// One unified-service run (serial when `workers == 0`).  Weight
/// generation/packing stays outside the timed loops: iterations pay one
/// clone of the prebuilt model, not a regeneration.
fn service_run(
    model: &BnnModel,
    events: &[PacketEvent],
    workers: usize,
    batch: usize,
) -> ServiceReport {
    let mut b = ServeBuilder::new()
        .backend(BackendFactory::single("fpga", model.clone()).unwrap())
        .trigger(TRIGGER)
        .output(OutputSelector::Memory)
        .pipeline(workers);
    if batch > 0 {
        b = b.batching(batch, 1e6);
    }
    b.build()
        .unwrap()
        .run(events.iter().cloned())
        .expect("healthy service run")
}

fn main() {
    let n_packets = if smoke_mode() { 20_000 } else { 200_000 };
    let evs = events(n_packets);
    let nn = model();

    // -- Equivalence gate (the reason verify.sh runs this in smoke mode).
    group("pipeline / serial-vs-pipelined equivalence (determinism contract)");
    let serial_rep = service_run(&nn, &evs, 0, 0);
    let want = (
        serial_rep.stats.triggers,
        serial_rep.stats.inferences,
        serial_rep.stats.classes.clone(),
    );
    for workers in WORKERS {
        for batch in BATCHES {
            let rep = service_run(&nn, &evs, workers, batch);
            let got = (rep.stats.triggers, rep.stats.inferences, rep.stats.classes);
            assert_eq!(
                got, want,
                "pipelined verdicts diverged from serial at workers={workers} batch={batch}"
            );
        }
    }
    println!(
        "equivalence ok: {} configs reproduce the serial verdict histogram {:?} \
         ({} triggers) on {} packets",
        WORKERS.len() * BATCHES.len(),
        want.2,
        want.0,
        n_packets
    );

    let mut rows: Vec<Row> = Vec::new();

    group("pipeline / serial mode (the single-thread baseline)");
    {
        let r = bench("serial", || service_run(&nn, &evs, 0, 0).stats.packets);
        rows.push(Row {
            mode: "serial",
            workers: 0,
            batch: 0,
            ns_per_pkt: r.ns_per_iter / n_packets as f64,
            mpkts_per_sec: n_packets as f64 * r.per_second() / 1e6,
            blocked: Vec::new(),
        });
    }

    group("pipeline / staged runtime (workers × batch)");
    for workers in WORKERS {
        for batch in BATCHES {
            let mut blocked: Vec<u64> = Vec::new();
            let r = bench(&format!("pipeline_w{workers}_b{batch}"), || {
                let rep = service_run(&nn, &evs, workers, batch);
                blocked = rep.stats.stage_blocked.clone();
                rep.stats.packets
            });
            rows.push(Row {
                mode: "pipeline",
                workers,
                batch,
                ns_per_pkt: r.ns_per_iter / n_packets as f64,
                mpkts_per_sec: n_packets as f64 * r.per_second() / 1e6,
                blocked,
            });
        }
    }

    println!("\n== pipeline summary ==");
    for r in &rows {
        let bp: String = STAGE_LINKS
            .iter()
            .zip(&r.blocked)
            .map(|(l, n)| format!("{l}:{n}"))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "{:8} w{} b{:<3} {:>7.2} Mpkt/s  ({:>6.1} ns/pkt)  {}",
            r.mode, r.workers, r.batch, r.mpkts_per_sec, r.ns_per_pkt, bp
        );
    }

    let fragment = obj(vec![
        ("model", Json::Str(MODEL_NAME.into())),
        ("smoke", Json::Bool(smoke_mode())),
        ("packets", Json::Num(n_packets as f64)),
        (
            "threads_available",
            Json::Num(std::thread::available_parallelism().map_or(1, |n| n.get()) as f64),
        ),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        obj(vec![
                            ("mode", Json::Str(r.mode.into())),
                            ("workers", Json::Num(r.workers as f64)),
                            ("batch", Json::Num(r.batch as f64)),
                            ("ns_per_pkt", Json::Num((r.ns_per_pkt * 10.0).round() / 10.0)),
                            (
                                "mpkts_per_sec",
                                Json::Num((r.mpkts_per_sec * 100.0).round() / 100.0),
                            ),
                            (
                                "stage_blocked",
                                Json::Arr(r.blocked.iter().map(|&b| Json::Num(b as f64)).collect()),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match write_bench_json("pipeline", fragment) {
        Ok(path) => println!("\nmerged into {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench json: {e}"),
    }
}

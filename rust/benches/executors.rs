//! Benches over the *real* executors (the perf-pass targets).
//!
//! Covers the hot paths behind Figs. 13/14 (traffic nets), Fig. 15
//! (tomography net), Fig. 25/26 (big FCs) — measured wall-clock on this
//! host via the in-tree harness (`n3ic::bench`), recorded in
//! EXPERIMENTS.md §Perf alongside the modeled numbers.
//!
//! Every row here drives a [`BackendFactory`] plane — the shipped
//! serving path — rather than a raw executor struct, so what this bench
//! times is what `serve --backend` actually runs.

use n3ic::bench::{bench, group};
use n3ic::bnn::{BnnLayer, BnnModel};
use n3ic::coordinator::{BackendFactory, InferencePlane};

fn main() {
    group("core_inference (one inference through the batch plane)");
    for (name, in_bits, arch) in [
        ("traffic_32_16_2", 256usize, vec![32usize, 16, 2]),
        ("tomo_128_64_2", 152, vec![128, 64, 2]),
        ("fc_4096x2048", 4096, vec![2048]),
    ] {
        let model = BnnModel::random(name, in_bits, &arch, 1);
        let x = BnnLayer::random(1, in_bits, 7).words;
        let mut plane = BackendFactory::single("batch", model).unwrap();
        bench(name, || plane.classify(0, std::hint::black_box(&x)).0);
    }

    // Since the batch-engine PR this runs the weight-stationary tiled
    // kernel — now behind the unified `host` backend of the
    // BackendFactory (see benches/batch_engine.rs for the full
    // serial/tiled/sharded comparison grid).
    group("bnnexec_batch (host backend, real wall clock)");
    let model = BnnModel::random("traffic", 256, &[32, 16, 2], 1);
    for batch in [32usize, 1024] {
        let inputs: Vec<Vec<u32>> = (0..batch)
            .map(|i| BnnLayer::random(1, 256, i as u64).words)
            .collect();
        let mut host = BackendFactory::single("host", model.clone()).unwrap();
        let mut classes = Vec::with_capacity(batch);
        let r = bench(&format!("batch{batch}"), || {
            host.run_batch(0, std::hint::black_box(&inputs), &mut classes);
            classes.len()
        });
        println!(
            "  -> {:.2}M inferences/s on this host (paper's Haswell: 1.18M/s)",
            batch as f64 * r.per_second() / 1e6
        );
    }

    group("pisa_interpreter (NNtoP4 functional path, via the pisa plane)");
    let mut pisa = BackendFactory::single("pisa", model.clone()).unwrap();
    let x = BnnLayer::random(1, 256, 3).words;
    bench("pisa_interpreter_traffic", || {
        pisa.classify(0, std::hint::black_box(&x)).0
    });

    group("qmlp_fixed_point (quantized-MLP plane)");
    let mut qmlp = BackendFactory::single("qmlp", model.clone()).unwrap();
    bench("qmlp_traffic", || qmlp.classify(0, std::hint::black_box(&x)).0);

    // The AOT/PJRT path (L1+L2 through XLA): per-call overhead vs the
    // native core — quantifies why the coordinator keeps the bit-exact
    // Rust path on the per-packet fast path and uses PJRT for batches.
    // Needs the off-by-default `pjrt` feature (vendored xla-rs).
    #[cfg(feature = "pjrt")]
    {
        let artifacts =
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if artifacts.join("manifest.json").exists() {
            group("pjrt_artifact (AOT JAX/Pallas via XLA)");
            let m = n3ic::bnn::BnnModel::load_named(&artifacts, "traffic")
                .unwrap_or_else(|_| BnnModel::random("traffic", 256, &[32, 16, 2], 1));
            let mut rt = n3ic::runtime::PjrtRuntime::new(&artifacts).unwrap();
            let key1 = n3ic::runtime::Manifest::key_for(&m, 1);
            let x1 = vec![BnnLayer::random(1, 256, 5).words];
            rt.infer_batch(&key1, &m, &x1).unwrap(); // warm compile
            bench("pjrt_batch1", || {
                rt.infer_batch(&key1, &m, std::hint::black_box(&x1)).unwrap()
            });
            let key256 = n3ic::runtime::Manifest::key_for(&m, 256);
            let x256: Vec<Vec<u32>> = (0..256)
                .map(|i| BnnLayer::random(1, 256, i).words)
                .collect();
            rt.infer_batch(&key256, &m, &x256).unwrap();
            let r = bench("pjrt_batch256", || {
                rt.infer_batch(&key256, &m, std::hint::black_box(&x256)).unwrap()
            });
            println!(
                "  -> {:.2}M inferences/s through the AOT artifact at batch 256",
                256.0 * r.per_second() / 1e6
            );
        }
    }
}

//! `registry` — cost of the multi-model registry on the serving path:
//!
//! 1. **Pin overhead**: single-input classify through a versioned
//!    [`MultiModelExecutor`] (one atomic load + `Arc` clone per pin) vs
//!    a raw [`BatchKernel`] — the price of hot-swappability at steady
//!    state.
//! 2. **Publish cost**: one hot swap end-to-end (pack + install), i.e.
//!    how fast a control plane can push retrained weights.
//! 3. **Swap storm**: batch classify while a writer thread republishes
//!    continuously — throughput under active hot-swapping, the
//!    zero-downtime claim measured rather than asserted.
//!
//! Results merge into the `benches.registry` entry of `BENCH.json`
//! (`BENCH.smoke.json` under `N3IC_BENCH_SMOKE=1`, as in verify.sh):
//!
//! ```text
//! cd rust && cargo bench --bench registry
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use n3ic::bench::{bench, group, smoke_mode, write_bench_json};
use n3ic::bnn::{BatchKernel, BnnLayer, BnnModel, MultiModelExecutor, RegistryHandle};
use n3ic::json::{obj, Json};

const MODEL_NAME: &str = "traffic_32_16_2";

fn model(seed: u64) -> BnnModel {
    BnnModel::random(MODEL_NAME, 256, &[32, 16, 2], seed)
}

fn main() {
    let registry = RegistryHandle::new();
    registry.publish("anomaly", &model(1)).unwrap();
    let names = vec!["anomaly".to_string()];
    let inputs: Vec<Vec<u32>> = (0..64)
        .map(|i| BnnLayer::random(1, 256, 7_000 + i).words)
        .collect();

    group("registry / steady-state pin overhead (single input)");
    let mut kernel = BatchKernel::new(&model(1));
    let raw = bench("raw_kernel_classify_one", || kernel.classify_one(&inputs[0]));
    let mut exec = MultiModelExecutor::new(&registry, &names, 100.0).unwrap();
    let pinned = bench("registry_classify_one", || exec.classify(0, &inputs[0]).0);
    let pin_overhead_ns = pinned.ns_per_iter - raw.ns_per_iter;
    println!(
        "pin overhead ≈ {pin_overhead_ns:.1} ns/inference \
         (version check + tag clone on top of the kernel)"
    );

    group("registry / publish (hot swap) cost");
    let swap_model = model(2);
    let publish = bench("publish_hot_swap", || {
        registry.publish("anomaly", &swap_model).unwrap().version()
    });

    group("registry / batch classify under a publish storm");
    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let registry = registry.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let (a, b) = (model(3), model(4));
            let mut flip = false;
            let mut published = 0u64;
            while !stop.load(Ordering::Relaxed) {
                flip = !flip;
                registry
                    .publish("anomaly", if flip { &a } else { &b })
                    .unwrap();
                published += 1;
                // ~2k swaps/s: an aggressive control plane, not a busy
                // loop that would just benchmark lock contention.
                std::thread::sleep(std::time::Duration::from_micros(500));
            }
            published
        })
    };
    let mut classes = Vec::new();
    let storm = bench("classify_batch64_under_swap_storm", || {
        exec.classify_batch(0, &inputs, &mut classes);
        classes.len()
    });
    stop.store(true, Ordering::Relaxed);
    let swaps_during_storm = writer.join().unwrap();
    println!("writer landed {swaps_during_storm} hot swaps during the storm bench");

    let fragment = obj(vec![
        ("model", Json::Str(MODEL_NAME.into())),
        ("smoke", Json::Bool(smoke_mode())),
        ("raw_kernel_ns", Json::Num((raw.ns_per_iter * 10.0).round() / 10.0)),
        ("registry_classify_ns", Json::Num((pinned.ns_per_iter * 10.0).round() / 10.0)),
        ("pin_overhead_ns", Json::Num((pin_overhead_ns * 10.0).round() / 10.0)),
        ("publish_ns", Json::Num(publish.ns_per_iter.round())),
        (
            "storm_batch64_ns",
            Json::Num(storm.ns_per_iter.round()),
        ),
        (
            "storm_mflows_per_sec",
            Json::Num((64.0 * storm.per_second() / 1e6 * 100.0).round() / 100.0),
        ),
        ("storm_swaps", Json::Num(swaps_during_storm as f64)),
    ]);
    match write_bench_json("registry", fragment) {
        Ok(path) => println!("\nmerged into {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench json: {e}"),
    }
}

//! `overload` — cost of the overload control plane on the serving path:
//!
//! 1. **Admission hot path**: one leaky-bucket admit decision per
//!    trigger — the arithmetic every packet pays once shedding is
//!    configured, whether or not it ever fires.
//! 2. **Serving under 5x overload**: the same burst served with and
//!    without shedding + trigger-only degradation.  The shed run
//!    retires fewer real inferences, which is the point — overload
//!    control converts queue collapse into saved compute.
//! 3. **Placement failover**: batch cost through a [`PlacedPlane`]
//!    whose cheapest member faults every call (breaker tripping +
//!    failover to the healthy member) vs the healthy member alone.
//!
//! Results merge into the `benches.overload` entry of `BENCH.json`
//! (`BENCH.smoke.json` under `N3IC_BENCH_SMOKE=1`, as in verify.sh):
//!
//! ```text
//! cd rust && cargo bench --bench overload
//! ```

use n3ic::bench::{bench, group, smoke_mode, write_bench_json};
use n3ic::bnn::{BnnLayer, BnnModel, EngineError, VersionTag};
use n3ic::coordinator::{
    AdmissionController, BackendFactory, BreakerPolicy, Capabilities, DegradeSpec,
    InferencePlane, OutputSelector, PacketEvent, PlacedPlane, ServeBuilder, ServiceReport,
    ShedPolicy, TriggerCondition,
};
use n3ic::json::{obj, Json};
use n3ic::net::traffic::CbrSpec;

fn model() -> BnnModel {
    BnnModel::random("traffic", 256, &[32, 16, 2], 1)
}

/// Member whose batch path always faults — breaker-bait in front of the
/// healthy fpga member in the failover bench.
struct FlakyPlane;

impl InferencePlane for FlakyPlane {
    fn capabilities(&self) -> Capabilities {
        Capabilities::single("flaky", 10.0)
    }

    fn classify(&mut self, _route: usize, _x: &[u32]) -> (usize, Option<VersionTag>) {
        unreachable!("the failover bench only drives the batch path");
    }

    fn try_run_batch(
        &mut self,
        _route: usize,
        _inputs: &[Vec<u32>],
        _classes: &mut Vec<usize>,
    ) -> Result<Option<VersionTag>, EngineError> {
        Err(EngineError::WorkerDied)
    }

    fn n_classes(&self) -> usize {
        2
    }
}

fn main() {
    group("overload / admission decision hot path");
    let mut adm = AdmissionController::new(ShedPolicy::new(400_000.0, 100_000.0), 1.0);
    let mut clock = 0.0f64;
    let decision = bench("admission_admit_per_trigger", || {
        // 40 Gb/s 256 B arrivals against 50 µs modeled work: the bucket
        // sawtooths through both admit and shed branches.
        clock += 51.2;
        adm.admit(clock, 50_000.0)
    });

    group("overload / serial serving under 5x modeled overload");
    let packets = if smoke_mode() { 8_000 } else { 60_000 };
    let events = PacketEvent::cbr_burst(CbrSpec { gbps: 40.0, pkt_size: 256 }, 400, 77, packets);
    let serve = |shed: bool| -> ServiceReport {
        let mut b = ServeBuilder::new()
            .backend(BackendFactory::custom("slownic", model(), 50_000.0, 1))
            .trigger(TriggerCondition::EveryNPackets(5))
            .output(OutputSelector::Memory);
        if shed {
            b = b
                .shed(ShedPolicy::new(400_000.0, 100_000.0))
                .degrade(DegradeSpec::trigger_only());
        }
        b.build().unwrap().run(events.iter().cloned()).unwrap()
    };
    let shed_run = bench("serve_shed_burst", || serve(true).stats.sheds);
    let unshed_run = bench("serve_unshed_burst", || serve(false).stats.inferences);
    let sample = serve(true);
    println!(
        "sample shed run: {} sheds, {} inferences, {} ladder steps",
        sample.stats.sheds,
        sample.stats.inferences,
        sample.degradation.len()
    );

    group("overload / placement failover (batch 8)");
    let inputs: Vec<Vec<u32>> = (0..8).map(|i| BnnLayer::random(1, 256, 9_100 + i).words).collect();
    let mut classes = Vec::new();
    let mut healthy = BackendFactory::single("fpga", model()).unwrap();
    let fpga_b8 = bench("fpga_batch8", || {
        healthy.try_run_batch(0, &inputs, &mut classes).unwrap();
        classes.len()
    });
    let mut placed = PlacedPlane::new(
        vec![Box::new(FlakyPlane), BackendFactory::single("fpga", model()).unwrap()],
        BreakerPolicy { trip_after: 2, cooldown_calls: 64, ..BreakerPolicy::default() },
    )
    .unwrap();
    let placed_b8 = bench("placed_faulting_member_batch8", || {
        placed.try_run_batch(0, &inputs, &mut classes).unwrap();
        classes.len()
    });

    let round1 = |v: f64| (v * 10.0).round() / 10.0;
    let fragment = obj(vec![
        ("smoke", Json::Bool(smoke_mode())),
        ("admission_decision_ns", Json::Num(round1(decision.ns_per_iter))),
        ("burst_packets", Json::Num(packets as f64)),
        (
            "shed_events_per_sec",
            Json::Num((packets as f64 * shed_run.per_second()).round()),
        ),
        (
            "unshed_events_per_sec",
            Json::Num((packets as f64 * unshed_run.per_second()).round()),
        ),
        ("sample_sheds", Json::Num(sample.stats.sheds as f64)),
        ("sample_inferences", Json::Num(sample.stats.inferences as f64)),
        ("sample_ladder_steps", Json::Num(sample.degradation.len() as f64)),
        ("fpga_batch8_ns", Json::Num(round1(fpga_b8.ns_per_iter))),
        (
            "placed_faulting_batch8_ns",
            Json::Num(round1(placed_b8.ns_per_iter)),
        ),
    ]);
    match write_bench_json("overload", fragment) {
        Ok(path) => println!("\nmerged into {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench json: {e}"),
    }
}

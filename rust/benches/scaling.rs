//! Benches for the device-model engines (Figs. 17/18, 21–31): the NFP
//! queueing simulation, the fat-tree discrete-event core, and the NNtoP4
//! compiler — the compute that regenerates the scaling figures — plus an
//! end-to-end serve-path cell so the shipped `ServeBuilder` pipeline
//! (packet clock → trigger → plane → sink) is timed here too, for the
//! batch and qmlp backends.

use n3ic::bench::{bench, group};
use n3ic::bnn::BnnModel;
use n3ic::coordinator::{
    BackendFactory, OutputSelector, PacketEvent, ServeBuilder, TriggerCondition,
};
use n3ic::fattree::{FatTreeSim, IncastWorkload, SimConfig, Topology};
use n3ic::net::traffic::CbrSpec;
use n3ic::nfp::{MemKind, NfpSim};
use n3ic::pisa::compile_bnn;

fn main() {
    group("simulation engines");
    let model = BnnModel::random("traffic", 256, &[32, 16, 2], 1);
    bench("nfp_sim_20k_events", || {
        let sim = NfpSim::new(&model, MemKind::Cls, 480);
        sim.run(1.81e6, 20_000, 3).completed_per_sec
    });

    bench("fattree_50_rounds", || {
        let topo = Topology::new();
        let cfg = SimConfig {
            probe_interval_ns: 1e6,
            ..SimConfig::default()
        };
        let mut wl = IncastWorkload::new(&topo, &cfg);
        let mut sim = FatTreeSim::new(topo, cfg, 1);
        sim.run(50, &mut wl).len()
    });

    group("compilers");
    bench("nntop4_compile_traffic", || {
        compile_bnn(std::hint::black_box(&model)).unwrap().total_ops()
    });

    // End to end through the unified service (a Service is consumed by
    // `run`, so each iteration rebuilds it; the event burst is prebuilt
    // and cloned per run).
    group("serve path (ServeBuilder, 5k CBR packets, trigger every 10)");
    let events =
        PacketEvent::cbr_burst(CbrSpec { gbps: 10.0, pkt_size: 256 }, 500, 11, 5_000);
    for backend in ["batch", "qmlp"] {
        bench(&format!("serve_5k_{backend}"), || {
            let rep = ServeBuilder::new()
                .backend(BackendFactory::single(backend, model.clone()).unwrap())
                .trigger(TriggerCondition::EveryNPackets(10))
                .output(OutputSelector::Memory)
                .build()
                .unwrap()
                .run(events.iter().cloned())
                .expect("healthy serve run");
            rep.stats.inferences
        });
    }
}

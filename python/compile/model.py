"""Layer-2: the binarized-MLP compute graph in JAX, calling the L1 kernels.

A BNN model here is a list of packed uint32 weight matrices
``[n_k, in_words_k]`` (see ``kernels/ref.py`` for the bit conventions).
Hidden layers apply the packed sign activation; the final layer returns raw
int32 popcount scores so the consumer (the Rust coordinator, or the paper's
output selector) can argmax / threshold them.

The forward pass lowers — kernels included — into a single HLO module via
``aot.py``; the Rust runtime executes it through PJRT with Python entirely
out of the request path.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import bnn as bnn_kernels
from .kernels import ref as bnn_ref
from .kernels.ref import BLOCK_SIZE, pack_bits, padded_bits


@dataclass(frozen=True)
class BnnArch:
    """Architecture of a binarized MLP: logical widths, unpadded.

    ``in_bits`` is the logical input width (e.g. 256 for the traffic use
    cases, 152 for tomography); ``neurons`` the per-layer neuron counts
    (e.g. (32, 16, 2)).
    """

    in_bits: int
    neurons: tuple[int, ...]

    @property
    def layer_in_bits(self) -> tuple[int, ...]:
        """Padded input width of each layer."""
        widths = [padded_bits(self.in_bits)]
        widths += [padded_bits(n) for n in self.neurons[:-1]]
        return tuple(widths)

    @property
    def weight_shapes(self) -> tuple[tuple[int, int], ...]:
        """Packed weight shapes [(n_k, in_words_k), ...]."""
        return tuple(
            (n, ib // BLOCK_SIZE)
            for n, ib in zip(self.neurons, self.layer_in_bits)
        )

    @property
    def total_weight_bits(self) -> int:
        return sum(n * ib for n, ib in zip(self.neurons, self.layer_in_bits))

    @property
    def memory_bytes(self) -> int:
        """Binary-model memory footprint (packed weights)."""
        return self.total_weight_bits // 8

    @property
    def float_memory_bytes(self) -> int:
        """Full-precision equivalent (4B/weight), for Table 1/5."""
        return self.total_weight_bits * 4

    def describe(self) -> str:
        ns = ", ".join(str(n) for n in self.neurons)
        return f"{self.in_bits}b → [{ns}]"


@dataclass
class BnnModel:
    """A trained, packed BNN: architecture + uint32 weight matrices."""

    arch: BnnArch
    weights: list[np.ndarray] = field(default_factory=list)

    def __post_init__(self) -> None:
        shapes = self.arch.weight_shapes
        if len(self.weights) != len(shapes):
            raise ValueError(
                f"{len(self.weights)} weight matrices for {len(shapes)} layers"
            )
        for k, (w, s) in enumerate(zip(self.weights, shapes)):
            if tuple(w.shape) != s:
                raise ValueError(f"layer {k}: shape {w.shape} != expected {s}")
            if w.dtype != np.uint32:
                raise ValueError(f"layer {k}: dtype {w.dtype} != uint32")

    @classmethod
    def from_pm1(cls, arch: BnnArch, layers_pm1: list[np.ndarray]) -> "BnnModel":
        """Build from ±1 float weight matrices [n_k, in_bits_k(padded)]."""
        packed = [pack_bits((w > 0).astype(np.uint32)) for w in layers_pm1]
        return cls(arch, packed)


def bnn_forward(weights: list[jax.Array], x_packed: jax.Array) -> jax.Array:
    """Full BNN forward on Pallas kernels: packed input → final int32 scores.

    This is the function ``aot.py`` lowers to HLO.  ``weights`` become
    compile-time constants when closed over, or runtime arguments when
    passed — we pass them as arguments so one artifact serves any model of
    the same architecture (runtime reconfiguration, like the paper's
    MAU-table weight store).
    """
    h = x_packed
    for w in weights[:-1]:
        h = bnn_kernels.bnn_fc(h, w)
    return bnn_kernels.bnn_fc_scores(h, weights[-1])


def bnn_forward_ref(weights: list[jax.Array], x_packed: jax.Array) -> jax.Array:
    """Same graph on the pure-jnp oracle (used in tests / L2 perf checks)."""
    return bnn_ref.bnn_mlp_ref(list(weights), x_packed)


def predict_classes(model: BnnModel, x_packed: np.ndarray) -> np.ndarray:
    """Convenience: argmax of the final scores (ties → lowest index)."""
    scores = bnn_forward([jnp.asarray(w) for w in model.weights],
                         jnp.asarray(x_packed))
    return np.asarray(jnp.argmax(scores, axis=-1))


# The paper's evaluated architectures (§5 Table 1, App. C Table 5).
USE_CASE_ARCHS: dict[str, BnnArch] = {
    # Traffic classification: 16 flow features × 16b = 256 inputs.
    "traffic": BnnArch(in_bits=256, neurons=(32, 16, 2)),
    # Anomaly detection: same shape, different dataset.
    "anomaly": BnnArch(in_bits=256, neurons=(32, 16, 2)),
    # Network tomography: 19 probe delays × 8b = 152 inputs, three sizes.
    "tomography_32": BnnArch(in_bits=152, neurons=(32, 16, 2)),
    "tomography_64": BnnArch(in_bits=152, neurons=(64, 32, 2)),
    "tomography_128": BnnArch(in_bits=152, neurons=(128, 64, 2)),
}

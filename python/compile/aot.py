"""AOT: lower the BNN forward pass (Pallas kernels included) to HLO text.

The interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Weights are lowered as *runtime arguments* (not baked constants) so one
artifact serves every trained model of the same architecture — the same
runtime-reconfigurability the paper gets from storing weights in MAU
tables / CLS memory.  Argument order: ``w_0, ..., w_{L-1}, x``.

Artifacts (per architecture × batch size)::

    artifacts/<key>_b<batch>.hlo.txt
    artifacts/manifest.json        # shapes + arg order for the Rust runtime
    artifacts/model.hlo.txt        # default target (mlp256, batch 1)

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.ref import BLOCK_SIZE
from .model import BnnArch, USE_CASE_ARCHS, bnn_forward

# Architectures to ship. "mlp256" covers both 256-bit traffic use cases;
# the tomography sizes share the 152-bit input.
AOT_ARCHS: dict[str, BnnArch] = {
    "mlp256": USE_CASE_ARCHS["traffic"],
    "tomo32": USE_CASE_ARCHS["tomography_32"],
    "tomo64": USE_CASE_ARCHS["tomography_64"],
    "tomo128": USE_CASE_ARCHS["tomography_128"],
}
BATCH_SIZES = (1, 32, 256)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_arch(arch: BnnArch, batch: int) -> str:
    """Lower ``bnn_forward`` for one architecture + batch size."""

    def fn(*args):
        *weights, x = args
        return (bnn_forward(list(weights), x),)

    w_specs = [
        jax.ShapeDtypeStruct(s, jnp.uint32) for s in arch.weight_shapes
    ]
    x_spec = jax.ShapeDtypeStruct(
        (batch, arch.weight_shapes[0][1]), jnp.uint32
    )
    lowered = jax.jit(fn).lower(*w_specs, x_spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    manifest = {}
    for key, arch in AOT_ARCHS.items():
        for batch in BATCH_SIZES:
            name = f"{key}_b{batch}"
            text = lower_arch(arch, batch)
            (out / f"{name}.hlo.txt").write_text(text)
            manifest[name] = {
                "file": f"{name}.hlo.txt",
                "in_bits": arch.in_bits,
                "neurons": list(arch.neurons),
                "batch": batch,
                "in_words": arch.weight_shapes[0][1],
                "weight_shapes": [list(s) for s in arch.weight_shapes],
                "out_neurons": arch.neurons[-1],
            }
            print(f"wrote {name}.hlo.txt ({len(text)} chars)")
    # Makefile's canonical default target.
    (out / "model.hlo.txt").write_text((out / "mlp256_b1.hlo.txt").read_text())
    manifest["model"] = dict(manifest["mlp256_b1"], file="model.hlo.txt")
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote manifest.json ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()

"""Layer-1 Pallas kernels: the binary fully-connected layer (Algorithm 1).

The paper's compute hot-spot is the XNOR + popcount + sign loop of a binary
FC layer.  This module implements it as Pallas kernels so the whole model
lowers into one HLO module (AOT'd by ``compile/aot.py`` and executed from
Rust via PJRT).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the NIC targets pack
weights into ``block_size``-bit registers (NFP: 32b), keep them resident in
the fastest memory (NFP CLS / FPGA BRAM), and popcount either with a lookup
table (FPGA) or a shift/mask/add tree (P4, HAKMEM AI memo 239 item 169).
On TPU the analogue is:

* packed ``uint32`` words on the innermost (lane) axis → one VPU op handles
  32 × vector-width binary synapses;
* weights + one batch tile in VMEM via ``BlockSpec`` → one HBM fetch of the
  weights per batch tile, exactly the "load once, stream inputs" schedule;
* popcount as the HAKMEM bit-slice tree (5 vector ops/word) rather than an
  LUT gather, which the VPU does not do efficiently.  The MXU is left idle
  on purpose: a binary layer is bitwise work, not a bf16 matmul.

Kernels MUST run with ``interpret=True`` here (CPU PJRT cannot execute
Mosaic custom-calls); correctness is asserted against ``ref.py`` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BLOCK_SIZE, padded_bits

# Batch-tile row count.  8×128 is the VPU register tile; 128 rows keeps the
# scores block (TB × N ≤ 128×128 int32 = 64KB) comfortably inside VMEM next
# to the packed weights (≤ 4KB for the paper's NNs).
MAX_BATCH_TILE = 128


def popcount_u32(v: jax.Array) -> jax.Array:
    """HAKMEM-169 bit-slice popcount over uint32 lanes (5 vector ops).

    Matches Algorithm 2 of the paper, which the NNtoP4 compiler unrolls
    across PISA pipeline stages; here the same tree vectorizes on the VPU.
    """
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    # Horizontal byte-sum via multiply-accumulate; the high byte holds the
    # total.  uint32 wrap-around is intentional and exact here.
    return (v * jnp.uint32(0x01010101)) >> 24


def _scores_kernel(x_ref, w_ref, o_ref):
    """Score tile: o[b, n] = sum_j popcount(~(x[b, j] ^ w[n, j]))."""
    x = x_ref[...]  # [TB, IW] uint32
    w = w_ref[...]  # [N, IW] uint32
    xnor = ~(x[:, None, :] ^ w[None, :, :])  # [TB, N, IW]
    o_ref[...] = jnp.sum(popcount_u32(xnor).astype(jnp.int32), axis=-1)


def _fc_kernel(x_ref, w_ref, o_ref, *, thr: int, n_out: int):
    """Packed binary FC tile: sign-threshold scores, pack bits into uint32."""
    x = x_ref[...]
    w = w_ref[...]
    xnor = ~(x[:, None, :] ^ w[None, :, :])
    scores = jnp.sum(popcount_u32(xnor).astype(jnp.int32), axis=-1)  # [TB, N]
    bits = (scores >= thr).astype(jnp.uint32)
    p = padded_bits(n_out)
    if p != n_out:
        bits = jnp.pad(bits, ((0, 0), (0, p - n_out)))
    words = bits.reshape(bits.shape[0], p // BLOCK_SIZE, BLOCK_SIZE)
    shifts = jnp.arange(BLOCK_SIZE, dtype=jnp.uint32)
    o_ref[...] = jnp.sum(words << shifts, axis=-1).astype(jnp.uint32)


def _batch_tile(batch: int) -> int:
    if batch <= MAX_BATCH_TILE:
        return batch
    if batch % MAX_BATCH_TILE != 0:
        raise ValueError(f"batch {batch} must divide by {MAX_BATCH_TILE}")
    return MAX_BATCH_TILE


def bnn_fc_scores(x_packed: jax.Array, w_packed: jax.Array) -> jax.Array:
    """Pallas binary-FC scores: int32[batch, n_neurons] popcount sums.

    Args:
      x_packed: uint32[batch, in_words].
      w_packed: uint32[n_neurons, in_words]; ``in_words`` must match.
    """
    b, iw = x_packed.shape
    n, iw_w = w_packed.shape
    if iw != iw_w:
        raise ValueError(f"in_words mismatch: x has {iw}, w has {iw_w}")
    tb = _batch_tile(b)
    return pl.pallas_call(
        _scores_kernel,
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, iw), lambda i: (i, 0)),   # stream batch tiles
            pl.BlockSpec((n, iw), lambda i: (0, 0)),    # weights resident
        ],
        out_specs=pl.BlockSpec((tb, n), lambda i: (i, 0)),
        interpret=True,
    )(x_packed, w_packed)


def bnn_fc(x_packed: jax.Array, w_packed: jax.Array) -> jax.Array:
    """Pallas packed binary FC layer (Algorithm 1).

    Returns uint32[batch, ceil(n/32)] packed sign bits, threshold =
    ``in_bits / 2`` over the padded input width.
    """
    b, iw = x_packed.shape
    n, iw_w = w_packed.shape
    if iw != iw_w:
        raise ValueError(f"in_words mismatch: x has {iw}, w has {iw_w}")
    thr = (iw * BLOCK_SIZE) // 2
    ow = padded_bits(n) // BLOCK_SIZE
    tb = _batch_tile(b)
    kernel = functools.partial(_fc_kernel, thr=thr, n_out=n)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, ow), jnp.uint32),
        grid=(b // tb,),
        in_specs=[
            pl.BlockSpec((tb, iw), lambda i: (i, 0)),
            pl.BlockSpec((n, iw), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, ow), lambda i: (i, 0)),
        interpret=True,
    )(x_packed, w_packed)


def vmem_footprint_bytes(batch: int, in_words: int, n_neurons: int) -> int:
    """Estimated VMEM bytes for one grid step of :func:`bnn_fc`.

    Used by DESIGN.md §Perf to check the kernel stays VMEM-resident:
    input tile + weights + xnor/popcount intermediate + scores + output.
    """
    tb = _batch_tile(batch)
    ow = padded_bits(n_neurons) // BLOCK_SIZE
    x_b = tb * in_words * 4
    w_b = n_neurons * in_words * 4
    inter_b = tb * n_neurons * in_words * 4  # xnor tile (dominant term)
    scores_b = tb * n_neurons * 4
    out_b = tb * ow * 4
    return x_b + w_b + inter_b + scores_b + out_b

"""Pure-jnp reference oracle for the binary fully-connected layer.

This module is the single source of truth for the semantics of the paper's
Algorithm 1 (N3IC, §3.1): for every output neuron,

    s        = sum_j popcount( XNOR(w[j], x[j]) )        # j over 32b words
    bit      = 1  if s >= sign_thr  else 0
    sign_thr = in_bits / 2

where ``in_bits`` is the (padded) number of binary inputs.  Inputs, weights
and outputs use the {0, 1} encoding of the {-1, +1} algebra: for ±1 vectors
``a``, ``b`` with bit encodings ``x``, ``w``::

    dot(a, b) = 2 * popcount(XNOR(x, w)) - in_bits

so ``s >= in_bits/2  <=>  dot >= 0`` — the sign activation.

Everything here is plain ``jax.numpy`` (no Pallas) and is used by pytest as
the correctness oracle for the Pallas kernel in :mod:`bnn` and, via exported
golden files, for every Rust executor (bnn-exec, NFP sim, PISA interp, FPGA
sim, PJRT runtime).

Packing convention (shared with Rust): bit ``i`` of the logical input vector
lives in word ``i // 32``, bit position ``i % 32`` (little-endian within the
word).  All logical widths are padded to a multiple of 32 with 0-bits
(i.e. -1 in the ±1 algebra); training uses the same padding, so thresholds
stay exact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

BLOCK_SIZE = 32  # the paper's block_size for the NFP / P4 targets


def padded_bits(n: int) -> int:
    """Logical width ``n`` padded up to a multiple of BLOCK_SIZE."""
    return ((n + BLOCK_SIZE - 1) // BLOCK_SIZE) * BLOCK_SIZE


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """Pack a (..., n_bits) 0/1 array into (..., ceil(n/32)) uint32 words.

    Bit i goes to word i//32, position i%32.  Pads with zeros.
    """
    bits = np.asarray(bits, dtype=np.uint32)
    n = bits.shape[-1]
    p = padded_bits(n)
    if p != n:
        pad = np.zeros(bits.shape[:-1] + (p - n,), dtype=np.uint32)
        bits = np.concatenate([bits, pad], axis=-1)
    words = bits.reshape(bits.shape[:-1] + (p // BLOCK_SIZE, BLOCK_SIZE))
    shifts = np.arange(BLOCK_SIZE, dtype=np.uint32)
    return (words << shifts).sum(axis=-1).astype(np.uint32)


def unpack_bits(words: np.ndarray, n_bits: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`; returns a (..., n_bits) 0/1 uint8 array."""
    words = np.asarray(words, dtype=np.uint32)
    shifts = np.arange(BLOCK_SIZE, dtype=np.uint32)
    bits = (words[..., :, None] >> shifts) & 1
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * BLOCK_SIZE,))
    return bits[..., :n_bits].astype(np.uint8)


def bnn_fc_scores_ref(x_packed: jax.Array, w_packed: jax.Array) -> jax.Array:
    """Reference: integer XNOR-popcount scores.

    Args:
      x_packed: uint32[batch, in_words] packed inputs.
      w_packed: uint32[n_neurons, in_words] packed weights.

    Returns:
      int32[batch, n_neurons] scores ``s`` (popcount sums).
    """
    xnor = ~(x_packed[:, None, :] ^ w_packed[None, :, :])  # [B, N, IW]
    pop = jax.lax.population_count(xnor.astype(jnp.uint32))
    return jnp.sum(pop.astype(jnp.int32), axis=-1)


def pack_bits_jnp(bits: jax.Array, n_bits: int) -> jax.Array:
    """jnp version of :func:`pack_bits` over the last axis (0/1 ints)."""
    p = padded_bits(n_bits)
    if p != n_bits:
        pad = jnp.zeros(bits.shape[:-1] + (p - n_bits,), dtype=bits.dtype)
        bits = jnp.concatenate([bits, pad], axis=-1)
    words = bits.reshape(bits.shape[:-1] + (p // BLOCK_SIZE, BLOCK_SIZE))
    shifts = jnp.arange(BLOCK_SIZE, dtype=jnp.uint32)
    return jnp.sum(words.astype(jnp.uint32) << shifts, axis=-1).astype(jnp.uint32)


def bnn_fc_ref(x_packed: jax.Array, w_packed: jax.Array) -> jax.Array:
    """Reference: packed binary FC layer (Algorithm 1).

    Returns uint32[batch, ceil(n_neurons/32)] packed activation bits with
    ``bit = s >= in_bits/2`` (``in_bits`` = padded input width).
    """
    n = w_packed.shape[0]
    in_bits = w_packed.shape[1] * BLOCK_SIZE
    thr = in_bits // 2
    scores = bnn_fc_scores_ref(x_packed, w_packed)
    bits = (scores >= thr).astype(jnp.uint32)  # [B, N]
    return pack_bits_jnp(bits, n)


def bnn_mlp_ref(layers: list[jax.Array], x_packed: jax.Array) -> jax.Array:
    """Reference multi-layer BNN: hidden layers sign-packed, final raw scores.

    Args:
      layers: list of uint32[n_k, in_words_k] packed weight matrices.
      x_packed: uint32[batch, in_words_0].

    Returns:
      int32[batch, n_last] final-layer scores (argmax = predicted class).
    """
    h = x_packed
    for w in layers[:-1]:
        h = bnn_fc_ref(h, w)
    return bnn_fc_scores_ref(h, layers[-1])


def float_mlp_ref(layers_pm1: list[np.ndarray], x_pm1: np.ndarray) -> np.ndarray:
    """±1-algebra float reference (cross-checks the packed semantics).

    ``layers_pm1`` are float matrices with entries in {-1, +1} shaped
    [n_k, in_bits_k]; ``x_pm1`` is [batch, in_bits_0] in {-1, +1}.
    Hidden activation is sign(dot) with sign(0) = +1.  Returns the final
    layer's integer scores ``s = (dot + in_bits) / 2``.

    Padding: both activations and weight columns are padded with -1 up to
    the next multiple of 32, mirroring the 0-bit padding of the packed path
    (pad positions always match, adding +1 each to the popcount score).
    """

    def pad_pm1(a: np.ndarray, p: int) -> np.ndarray:
        if a.shape[1] < p:  # pad with -1 (the 0-bit)
            a = np.concatenate([a, -np.ones((a.shape[0], p - a.shape[1]))], axis=1)
        return a

    h = np.asarray(x_pm1, dtype=np.float64)
    for w in layers_pm1[:-1]:
        p = padded_bits(w.shape[1])
        h, w = pad_pm1(h, p), pad_pm1(np.asarray(w, np.float64), p)
        h = np.where(h @ w.T >= 0, 1.0, -1.0)
    w = layers_pm1[-1]
    p = padded_bits(w.shape[1])
    h, w = pad_pm1(h, p), pad_pm1(np.asarray(w, np.float64), p)
    return ((h @ w.T + p) / 2).astype(np.int64)

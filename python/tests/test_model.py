"""L2 tests: model architecture math, forward pass, export formats."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.model import BnnArch, BnnModel, bnn_forward, bnn_forward_ref, USE_CASE_ARCHS
from train.export import golden_for, model_to_dict


def random_model(arch: BnnArch, seed=0) -> BnnModel:
    rng = np.random.default_rng(seed)
    pm1 = [
        rng.choice([-1.0, 1.0], size=(n, ib))
        for n, ib in zip(arch.neurons, arch.layer_in_bits)
    ]
    return BnnModel.from_pm1(arch, pm1)


def test_arch_shapes_and_memory():
    a = USE_CASE_ARCHS["traffic"]
    assert a.weight_shapes == ((32, 8), (16, 1), (2, 1))
    assert a.memory_bytes == 1096  # Table 1: 1.1 KB
    assert a.float_memory_bytes == 35072  # Table 5: 35 KB
    t = USE_CASE_ARCHS["tomography_128"]
    assert t.weight_shapes == ((128, 5), (64, 4), (2, 2))
    assert 3300 < t.memory_bytes < 3700  # Table 5: 3.4 KB


def test_forward_kernel_vs_ref_all_archs():
    rng = np.random.default_rng(1)
    for name, arch in USE_CASE_ARCHS.items():
        model = random_model(arch, seed=hash(name) % 2**31)
        w = [jnp.asarray(x) for x in model.weights]
        x = jnp.asarray(
            rng.integers(0, 2**32, size=(4, arch.weight_shapes[0][1]), dtype=np.uint32)
        )
        np.testing.assert_array_equal(
            np.asarray(bnn_forward(w, x)), np.asarray(bnn_forward_ref(w, x)), err_msg=name
        )


def test_model_shape_validation():
    arch = USE_CASE_ARCHS["traffic"]
    model = random_model(arch)
    bad = [w.copy() for w in model.weights]
    bad[0] = bad[0][:, :-1]
    with pytest.raises(ValueError):
        BnnModel(arch, bad)


def test_export_roundtrip_schema():
    arch = USE_CASE_ARCHS["anomaly"]
    model = random_model(arch, seed=7)
    d = model_to_dict("anomaly", model, {"bnn_test_acc": 0.85})
    text = json.dumps(d)
    back = json.loads(text)
    assert back["neurons"] == [32, 16, 2]
    assert back["layers"][0]["threshold"] == 128
    assert len(back["layers"][0]["words"]) == 32 * 8
    # thresholds are half the padded input width for every layer
    for lyr in back["layers"]:
        assert lyr["threshold"] == lyr["in_words"] * 16


def test_golden_consistency():
    arch = USE_CASE_ARCHS["traffic"]
    model = random_model(arch, seed=3)
    g = golden_for("traffic", model, n_vectors=4)
    assert len(g["inputs"]) == 4
    for x, scores, cls in zip(g["inputs"], g["scores"], g["classes"]):
        xp = jnp.asarray(np.array([x], dtype=np.uint32))
        want = np.asarray(
            ref.bnn_mlp_ref([jnp.asarray(w) for w in model.weights], xp)
        )[0]
        np.testing.assert_array_equal(np.array(scores), want)
        assert cls == int(want.argmax())

"""Export-format tests: model JSON, goldens, and the cross-language
feature-layout golden consumed by the Rust test suite."""

import json
from pathlib import Path

import numpy as np

from compile.kernels.ref import pack_bits
from train.binarize import featurize
from train.export import write_feature_layout_golden


def test_feature_layout_golden_contents(tmp_path: Path):
    write_feature_layout_golden(tmp_path)
    data = json.loads((tmp_path / "feature_layout.golden.json").read_text())
    cases = data["cases"]
    assert len(cases) == 8
    shapes = {(len(c["values"]), c["feature_bits"], c["in_bits"]) for c in cases}
    assert shapes == {(16, 16, 256), (19, 8, 152)}
    for c in cases:
        # Each case is internally consistent: recompute the packing.
        x = np.array([c["values"]], dtype=np.uint16)
        pm1 = featurize(x, c["feature_bits"], c["in_bits"])
        packed = pack_bits((pm1 > 0).astype(np.uint32))[0]
        assert [int(w) for w in packed] == c["packed"]
        # Word count matches the padded width.
        assert len(c["packed"]) == (c["in_bits"] + 31) // 32


def test_feature_layout_golden_deterministic(tmp_path: Path):
    a = tmp_path / "a"
    b = tmp_path / "b"
    write_feature_layout_golden(a)
    write_feature_layout_golden(b)
    assert (a / "feature_layout.golden.json").read_text() == (
        b / "feature_layout.golden.json"
    ).read_text()

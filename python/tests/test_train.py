"""Training-stack tests: featurization, datasets, quick STE convergence."""

import numpy as np
import pytest

from compile.kernels.ref import pack_bits
from compile.model import BnnArch
from train import datasets
from train.binarize import featurize, train_bnn


def test_featurize_bit_layout_matches_pack():
    # One 16-bit feature value 0x8001 → MSB-first bits 1,0,...,0,1.
    x = np.array([[0x8001] + [0] * 15], dtype=np.uint16)
    out = featurize(x, 16, 256)
    assert out.shape == (1, 256)
    assert out[0, 0] == 1.0 and out[0, 15] == 1.0
    assert (out[0, 1:15] == -1.0).all()
    # Packing the 0/1 view must set word-0 bits 0 and 15.
    packed = pack_bits((out > 0).astype(np.uint32))
    assert packed[0, 0] == (1 | (1 << 15))


def test_featurize_pads_with_minus_one():
    x = np.zeros((2, 19), dtype=np.uint8)
    out = featurize(x, 8, 152)
    assert out.shape == (2, 160)
    assert (out[:, 152:] == -1.0).all()


def test_datasets_deterministic_and_balanced():
    a = datasets.make_traffic_classification(n=2000, seed=5)
    b = datasets.make_traffic_classification(n=2000, seed=5)
    np.testing.assert_array_equal(a.x, b.x)
    assert 0.4 < a.y.mean() < 0.6
    c = datasets.make_anomaly_detection(n=2000, seed=5)
    assert 0.4 < c.y.mean() < 0.6
    assert a.x.dtype == np.uint16


def test_tomography_dataset_structure():
    ds, labels = datasets.make_tomography(n=1500, seed=2)
    assert ds.x.shape == (1500, datasets.N_PROBES)
    assert labels.shape == (1500, datasets.N_QUEUES)
    assert ds.x.dtype == np.uint8
    # ~25% congested per queue by construction.
    frac = labels.mean(axis=0)
    assert (frac > 0.1).all() and (frac < 0.45).all()


def test_probe_paths_cover_all_queues():
    m = datasets.probe_path_matrix()
    assert m.shape == (datasets.N_PROBES, datasets.N_QUEUES)
    assert (m.sum(axis=0) >= 1).all()
    assert (m.sum(axis=1) >= 2).all()


@pytest.mark.slow
def test_ste_training_learns_separable_problem():
    # A tiny, clearly separable problem must exceed 85% after few epochs.
    rng = np.random.default_rng(0)
    n = 2000
    y = rng.integers(0, 2, n)
    x = np.where(y[:, None] == 1, 40000, 20000) + rng.normal(0, 3000, (n, 4))
    x = np.clip(x, 0, 65535).astype(np.uint16)
    arch = BnnArch(in_bits=64, neurons=(16, 2))
    res = train_bnn(arch, x[:1500], y[:1500], x[1500:], y[1500:], 16,
                    epochs=25, seed=1)
    # ±1-only weights with Algorithm 1's fixed threshold cap what a 64-bit
    # toy problem can reach; well above chance is the signal here (the
    # real use-case datasets land at 0.88–0.94, asserted via artifacts).
    assert res.test_acc > 0.75, res.test_acc

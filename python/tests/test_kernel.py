"""L1 correctness: Pallas kernel vs pure-jnp oracle (the core signal).

Hypothesis sweeps shapes and random packed inputs; every case asserts exact
integer equality (binary algebra — no tolerance needed).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import bnn, ref


def rand_packed(rng, rows, words):
    return rng.integers(0, 2**32, size=(rows, words), dtype=np.uint32)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.sampled_from([1, 2, 7, 32]),
    in_words=st.sampled_from([1, 2, 5, 8]),
    neurons=st.sampled_from([1, 2, 16, 32, 33, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_scores_kernel_matches_ref(batch, in_words, neurons, seed):
    rng = np.random.default_rng(seed)
    x = rand_packed(rng, batch, in_words)
    w = rand_packed(rng, neurons, in_words)
    got = np.asarray(bnn.bnn_fc_scores(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(ref.bnn_fc_scores_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.sampled_from([1, 3, 32]),
    in_words=st.sampled_from([1, 4, 8]),
    neurons=st.sampled_from([2, 16, 32, 48, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fc_kernel_matches_ref(batch, in_words, neurons, seed):
    rng = np.random.default_rng(seed)
    x = rand_packed(rng, batch, in_words)
    w = rand_packed(rng, neurons, in_words)
    got = np.asarray(bnn.bnn_fc(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(ref.bnn_fc_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=40, deadline=None)
@given(v=st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=64))
def test_popcount_u32(v):
    arr = jnp.asarray(np.array(v, dtype=np.uint32))
    got = np.asarray(bnn.popcount_u32(arr))
    want = np.array([bin(x).count("1") for x in v], dtype=np.uint32)
    np.testing.assert_array_equal(got, want)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    for n_bits in [1, 31, 32, 33, 152, 256]:
        bits = rng.integers(0, 2, size=(5, n_bits)).astype(np.uint8)
        packed = ref.pack_bits(bits)
        assert packed.shape == (5, ref.padded_bits(n_bits) // 32)
        np.testing.assert_array_equal(ref.unpack_bits(packed, n_bits), bits)


def test_scores_against_pm1_float_reference():
    """XNOR-popcount algebra == ±1 dot-product algebra, end to end."""
    rng = np.random.default_rng(7)
    dims = [64, 32, 16, 4]
    layers_pm1 = [
        rng.choice([-1.0, 1.0], size=(dims[k + 1], dims[k]))
        for k in range(len(dims) - 1)
    ]
    x_bits = rng.integers(0, 2, size=(16, dims[0]))
    x_pm1 = np.where(x_bits > 0, 1.0, -1.0)
    packed_layers = [
        jnp.asarray(ref.pack_bits((w > 0).astype(np.uint32)))
        for w in layers_pm1
    ]
    x_packed = jnp.asarray(ref.pack_bits(x_bits))
    got = np.asarray(ref.bnn_mlp_ref(packed_layers, x_packed))
    want = ref.float_mlp_ref(layers_pm1, x_pm1)
    np.testing.assert_array_equal(got, want)


def test_mismatched_words_raises():
    x = jnp.zeros((1, 2), jnp.uint32)
    w = jnp.zeros((4, 3), jnp.uint32)
    with pytest.raises(ValueError):
        bnn.bnn_fc_scores(x, w)
    with pytest.raises(ValueError):
        bnn.bnn_fc(x, w)


def test_vmem_footprint_small_nets_fit():
    # Paper's use-case nets must fit VMEM (≈16MB) with huge headroom.
    fp = bnn.vmem_footprint_bytes(batch=128, in_words=8, n_neurons=32)
    assert fp < 1 << 20  # < 1MB

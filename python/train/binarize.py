"""Courbariaux–Bengio binarization training (straight-through estimator).

Implements the paper's §3.1 training recipe: canonical back-propagation on
latent real-valued weights clipped to [-1, 1]; forward pass uses the sign of
the weights and sign activations; gradients flow through sign via the
straight-through estimator (identity inside the clip region).  After
training, weights are thresholded at 0 → {0, 1} bits and packed for the
XNOR-popcount executors.

The float (non-binarized) MLP baseline for Table 1/5's "MLP" column is also
trained here.  Optimizer is a self-contained Adam (no optax dependency).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import padded_bits
from compile.model import BnnArch, BnnModel


def featurize(x_int: np.ndarray, feature_bits: int, in_bits: int) -> np.ndarray:
    """Expand integer features to ±1 bit inputs, padded to ``in_bits``.

    Each feature contributes its binary digits MSB-first ("provide each bit
    as separated input to the MLP", App. C).  Pad positions are -1.
    """
    n, f = x_int.shape
    shifts = np.arange(feature_bits - 1, -1, -1)
    bits = (x_int[:, :, None].astype(np.int64) >> shifts) & 1
    bits = bits.reshape(n, f * feature_bits)
    assert bits.shape[1] <= in_bits
    out = -np.ones((n, padded_bits(in_bits)), dtype=np.float32)
    out[:, : bits.shape[1]] = np.where(bits > 0, 1.0, -1.0)
    return out


def _pad_pm1(h: jax.Array, width: int) -> jax.Array:
    """Pad activations with -1 up to ``width`` (the packed 0-bit padding)."""
    if h.shape[1] < width:
        h = jnp.concatenate(
            [h, -jnp.ones((h.shape[0], width - h.shape[1]), h.dtype)], axis=1
        )
    return h


def _ste_sign(x: jax.Array) -> jax.Array:
    """sign(x) with sign(0)=+1; backward = identity clipped to [-1, 1]."""
    xc = jnp.clip(x, -1.0, 1.0)
    s = jnp.where(x >= 0, 1.0, -1.0)
    return xc + jax.lax.stop_gradient(s - xc)


def _init_params(arch: BnnArch, key: jax.Array) -> list[jax.Array]:
    dims_in = [padded_bits(b) for b in arch.layer_in_bits]
    params = []
    for n, d in zip(arch.neurons, dims_in):
        key, sub = jax.random.split(key)
        params.append(jax.random.uniform(sub, (n, d), minval=-0.9, maxval=0.9))
    return params


def _bnn_forward_train(params: list[jax.Array], x: jax.Array) -> jax.Array:
    """Training-time forward: mirrors the packed inference path exactly.

    Hidden activations are ±1 signs of the binary dot; pre-activations are
    normalized by fan-in before the STE so the clip region is meaningful.
    The final layer returns the (scaled) binary dot as logits.
    """
    h = x
    for w in params[:-1]:
        h = _pad_pm1(h, w.shape[1])
        wb = _ste_sign(w)
        pre = h @ wb.T / w.shape[1]  # normalized binary dot
        h = _ste_sign(pre)
    w = params[-1]
    h = _pad_pm1(h, w.shape[1])
    return h @ _ste_sign(w).T / jnp.sqrt(w.shape[1])


def _xent(logits: jax.Array, y: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


@dataclass
class TrainResult:
    model: BnnModel
    train_acc: float
    test_acc: float


def _adam_update(grads, params, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, v, grads)
    mh = jax.tree.map(lambda m_: m_ / (1 - b1**t), m)
    vh = jax.tree.map(lambda v_: v_ / (1 - b2**t), v)
    params = jax.tree.map(
        lambda p, m_, v_: jnp.clip(p - lr * m_ / (jnp.sqrt(v_) + eps), -1.0, 1.0),
        params, mh, vh,
    )
    return params, m, v


def train_bnn(
    arch: BnnArch,
    x_train: np.ndarray,
    y_train: np.ndarray,
    x_test: np.ndarray,
    y_test: np.ndarray,
    feature_bits: int,
    *,
    epochs: int = 120,
    batch: int = 512,
    lr: float = 5e-3,
    seed: int = 0,
) -> TrainResult:
    """Train a binarized MLP; returns the packed model + accuracies."""
    xt = jnp.asarray(featurize(x_train, feature_bits, arch.in_bits))
    xe = jnp.asarray(featurize(x_test, feature_bits, arch.in_bits))
    yt, ye = jnp.asarray(y_train), jnp.asarray(y_test)
    key = jax.random.PRNGKey(seed)
    params = _init_params(arch, key)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, m, v, t, lr_t, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p: _xent(_bnn_forward_train(p, xb), yb)
        )(params)
        params, m, v = _adam_update(grads, params, m, v, t, lr_t)
        return params, m, v, loss

    @jax.jit
    def accuracy(params, x, y):
        pred = jnp.argmax(_bnn_forward_train(params, x), axis=-1)
        return jnp.mean(pred == y)

    n = xt.shape[0]
    steps_per_epoch = max(1, n // batch)
    rng = np.random.default_rng(seed)
    t = 0
    for e in range(epochs):
        # Cosine decay helps the latent weights settle near their final
        # signs; without it sign flips keep churning late in training.
        lr_t = lr * 0.5 * (1 + np.cos(np.pi * e / epochs))
        order = rng.permutation(n)
        for s in range(steps_per_epoch):
            idx = order[s * batch : (s + 1) * batch]
            t += 1
            params, m, v, _ = step(params, m, v, t, lr_t, xt[idx], yt[idx])

    pm1 = [np.where(np.asarray(w) >= 0, 1.0, -1.0) for w in params]
    model = BnnModel.from_pm1(arch, pm1)
    # Report accuracy of the *deployed* packed model (exact integer path),
    # not the training surrogate.
    from compile.kernels.ref import bnn_mlp_ref, pack_bits

    def packed_acc(x_pm1, y):
        xp = jnp.asarray(pack_bits((np.asarray(x_pm1) > 0).astype(np.uint32)))
        scores = bnn_mlp_ref([jnp.asarray(w) for w in model.weights], xp)
        return float(jnp.mean(jnp.argmax(scores, axis=-1) == y))

    return TrainResult(
        model=model,
        train_acc=packed_acc(xt, yt),
        test_acc=packed_acc(xe, ye),
    )


def train_float_mlp(
    arch: BnnArch,
    x_train: np.ndarray,
    y_train: np.ndarray,
    x_test: np.ndarray,
    y_test: np.ndarray,
    feature_bits: int,
    *,
    epochs: int = 60,
    batch: int = 512,
    lr: float = 1e-3,
    seed: int = 0,
) -> float:
    """Full-precision MLP baseline (ReLU + bias); returns test accuracy.

    Same widths as the BNN; this is the "MLP" column of Table 1/5.
    """
    xt = jnp.asarray(featurize(x_train, feature_bits, arch.in_bits))
    xe = jnp.asarray(featurize(x_test, feature_bits, arch.in_bits))
    yt, ye = jnp.asarray(y_train), jnp.asarray(y_test)
    key = jax.random.PRNGKey(seed + 100)
    dims_in = [padded_bits(b) for b in arch.layer_in_bits]
    params = []
    for n_, d in zip(arch.neurons, dims_in):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (n_, d)) * jnp.sqrt(2.0 / d)
        params.append({"w": w, "b": jnp.zeros((n_,))})

    def fwd(params, x):
        h = x
        for lyr in params[:-1]:
            h = _pad_pm1(h, lyr["w"].shape[1])
            h = jax.nn.relu(h @ lyr["w"].T + lyr["b"])
        h = _pad_pm1(h, params[-1]["w"].shape[1])
        return h @ params[-1]["w"].T + params[-1]["b"]

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, m, v, t, xb, yb):
        loss, grads = jax.value_and_grad(lambda p: _xent(fwd(p, xb), yb))(params)
        m = jax.tree.map(lambda m_, g: 0.9 * m_ + 0.1 * g, m, grads)
        v = jax.tree.map(lambda v_, g: 0.999 * v_ + 0.001 * g * g, v, grads)
        params = jax.tree.map(
            lambda p, m_, v_: p
            - lr * (m_ / (1 - 0.9**t)) / (jnp.sqrt(v_ / (1 - 0.999**t)) + 1e-8),
            params, m, v,
        )
        return params, m, v

    n = xt.shape[0]
    steps_per_epoch = max(1, n // batch)
    rng = np.random.default_rng(seed)
    t = 0
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(steps_per_epoch):
            idx = order[s * batch : (s + 1) * batch]
            t += 1
            params, m, v = step(params, m, v, t, xt[idx], yt[idx])
    pred = jnp.argmax(fwd(params, xe), axis=-1)
    return float(jnp.mean(pred == ye))

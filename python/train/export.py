"""Export trained BNNs + golden vectors for the Rust layer.

Formats (consumed by ``rust/src/bnn/model.rs`` via serde):

``artifacts/models/<name>.json``::

    {
      "name": "traffic",
      "in_bits": 256,                  # logical input width
      "neurons": [32, 16, 2],
      "layers": [
        {"neurons": 32, "in_words": 8, "threshold": 128,
         "words": [u32, ...]}          # row-major [neurons × in_words]
      ],
      "metrics": {"bnn_test_acc": .., "float_test_acc": ..,
                  "memory_bytes": .., "float_memory_bytes": ..}
    }

``artifacts/models/<name>.golden.json``: packed inputs + final scores +
argmax classes computed through the **Pallas kernel path** (so every Rust
executor is cross-checked against L1, not just the jnp oracle).
"""

from __future__ import annotations

import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import BLOCK_SIZE, pack_bits
from compile.model import BnnModel, bnn_forward


def model_to_dict(name: str, model: BnnModel, metrics: dict) -> dict:
    arch = model.arch
    layers = []
    for w, in_bits in zip(model.weights, arch.layer_in_bits):
        layers.append({
            "neurons": int(w.shape[0]),
            "in_words": int(w.shape[1]),
            "threshold": int(in_bits // 2),
            "words": [int(v) for v in w.reshape(-1)],
        })
    return {
        "name": name,
        "in_bits": int(arch.in_bits),
        "neurons": [int(n) for n in arch.neurons],
        "layers": layers,
        "metrics": metrics,
    }


def golden_for(name: str, model: BnnModel, n_vectors: int = 16,
               seed: int = 99) -> dict:
    rng = np.random.default_rng(seed)
    in_words = model.arch.weight_shapes[0][1]
    x = rng.integers(0, 2**32, size=(n_vectors, in_words), dtype=np.uint32)
    scores = np.asarray(
        bnn_forward([jnp.asarray(w) for w in model.weights], jnp.asarray(x))
    )
    return {
        "model": name,
        "in_words": in_words,
        "inputs": [[int(v) for v in row] for row in x],
        "scores": [[int(v) for v in row] for row in scores],
        "classes": [int(c) for c in scores.argmax(axis=1)],
    }


def write_model(out_dir: Path, name: str, model: BnnModel, metrics: dict) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{name}.json").write_text(
        json.dumps(model_to_dict(name, model, metrics)))
    (out_dir / f"{name}.golden.json").write_text(
        json.dumps(golden_for(name, model)))


def write_feature_layout_golden(out_dir: Path, seed: int = 77) -> None:
    """Cross-language golden: quantized features → packed input words.

    Pins the MSB-first, feature-major bit layout shared by
    ``train.binarize.featurize`` (training) and the Rust
    ``net::features`` module (runtime); checked by pytest *and* cargo
    test so the two ends can never drift apart silently.
    """
    from train.binarize import featurize

    rng = np.random.default_rng(seed)
    cases = []
    for n_feat, bits, in_bits in [(16, 16, 256), (19, 8, 152)]:
        for _ in range(4):
            vals = rng.integers(0, 2**bits, n_feat).tolist()
            x = np.array([vals], dtype=np.uint16)
            pm1 = featurize(x, bits, in_bits)
            packed = pack_bits((pm1 > 0).astype(np.uint32))[0].tolist()
            cases.append({
                "values": vals,
                "feature_bits": bits,
                "in_bits": in_bits,
                "packed": [int(w) for w in packed],
            })
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / "feature_layout.golden.json").write_text(
        json.dumps({"cases": cases}))

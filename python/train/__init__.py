# Build-time training package: synthetic datasets + STE binarization.
# Never imported at runtime; `make artifacts` runs it once.

"""Train every use-case BNN and export models + goldens + summary.

Regenerates the accuracy side of the paper's evaluation:

* Table 1 / Table 5 — per-use-case NN size, memory, MLP vs binarized
  accuracy (``artifacts/summary.json``).
* Fig 16 / Fig 34 — tomography accuracy distribution across queues for the
  three NN sizes (``artifacts/tomography_accuracy.json``).

Usage::

    python -m train.run_all [--out ../artifacts] [--full] [--quick]

``--full`` trains all 17 tomography queues (paper's box plot); the default
trains 5 representative queues to keep `make artifacts` fast.  ``--quick``
cuts epochs (CI smoke).  Deterministic for a fixed flag set.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from compile.model import USE_CASE_ARCHS
from train import datasets
from train.binarize import train_bnn, train_float_mlp
from train.export import write_model


def train_use_case(name, arch, ds, *, epochs, float_epochs, lr=5e-3, seed=0):
    (xt, yt), (xe, ye) = ds.split()
    res = train_bnn(arch, xt, yt, xe, ye, ds.feature_bits,
                    epochs=epochs, lr=lr, seed=seed)
    float_acc = train_float_mlp(arch, xt, yt, xe, ye, ds.feature_bits,
                                epochs=float_epochs, seed=seed)
    metrics = {
        "bnn_test_acc": round(res.test_acc, 4),
        "bnn_train_acc": round(res.train_acc, 4),
        "float_test_acc": round(float_acc, 4),
        "memory_bytes": arch.memory_bytes,
        "float_memory_bytes": arch.float_memory_bytes,
    }
    return res.model, metrics


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="all 17 tomography queues (slow)")
    ap.add_argument("--quick", action="store_true", help="reduced epochs")
    args = ap.parse_args()
    out = Path(args.out)
    models_dir = out / "models"
    e_bnn = 20 if args.quick else 60
    e_flt = 10 if args.quick else 40
    e_tomo = 30 if args.quick else 150

    summary = {}

    print("[traffic] training ...", flush=True)
    ds = datasets.make_traffic_classification()
    model, metrics = train_use_case(
        "traffic", USE_CASE_ARCHS["traffic"], ds,
        epochs=e_bnn, float_epochs=e_flt)
    write_model(models_dir, "traffic", model, metrics)
    summary["traffic"] = metrics
    print(f"[traffic] bnn={metrics['bnn_test_acc']} float={metrics['float_test_acc']}")

    print("[anomaly] training ...", flush=True)
    ds = datasets.make_anomaly_detection()
    model, metrics = train_use_case(
        "anomaly", USE_CASE_ARCHS["anomaly"], ds,
        epochs=e_bnn, float_epochs=e_flt)
    write_model(models_dir, "anomaly", model, metrics)
    summary["anomaly"] = metrics
    print(f"[anomaly] bnn={metrics['bnn_test_acc']} float={metrics['float_test_acc']}")

    # Tomography: one binary classifier per monitored queue, three NN sizes.
    ds, labels_all = datasets.make_tomography()
    queues = range(datasets.N_QUEUES) if args.full else [0, 4, 8, 12, 16]
    tomo_acc: dict[str, dict[str, float]] = {}
    for size in (32, 64, 128):
        arch = USE_CASE_ARCHS[f"tomography_{size}"]
        accs = {}
        for q in queues:
            dq = datasets.Dataset(x=ds.x, y=labels_all[:, q],
                                  feature_bits=8, name=f"tomo_q{q}")
            model, metrics = train_use_case(
                f"tomography_{size}_q{q}", arch, dq,
                epochs=e_tomo // 2 if args.quick else e_tomo,
                float_epochs=e_flt, lr=8e-3, seed=q)
            accs[f"q{q}"] = metrics
            # Queue 0 is the canonical model used by the Rust benches.
            if q == 0:
                write_model(models_dir, f"tomography_{size}", model, metrics)
        tomo_acc[str(size)] = {
            k: v["bnn_test_acc"] for k, v in accs.items()}
        tomo_acc[f"{size}_float"] = {
            k: v["float_test_acc"] for k, v in accs.items()}
        med = sorted(tomo_acc[str(size)].values())[len(accs) // 2]
        summary[f"tomography_{size}"] = {
            "median_bnn_acc": med,
            "memory_bytes": arch.memory_bytes,
            "float_memory_bytes": arch.float_memory_bytes,
        }
        print(f"[tomography_{size}] median bnn acc={med}")

    (out / "tomography_accuracy.json").write_text(json.dumps(tomo_acc, indent=1))
    (out / "summary.json").write_text(json.dumps(summary, indent=1))
    from train.export import write_feature_layout_golden

    write_feature_layout_golden(out)
    print(f"wrote {out}/summary.json")


if __name__ == "__main__":
    main()

"""Synthetic dataset generators replacing the paper's proprietary data.

Substitutions (DESIGN.md §Substitutions #5/#6):

* **UPC-AAU** (traffic classification, P2P vs rest) and **UNSW-NB15**
  (anomaly detection, good vs bad) are not redistributable here.  We keep
  the exact *learning problem* — 16 chi-squared-selected flow-level
  features, each quantized to 16 bits and fed bit-by-bit to a small MLP —
  and replace the sampling distribution with class-conditional generative
  models of flow statistics (packet sizes, inter-arrival times, byte
  counts, port entropy, direction ratios, ...).  Class overlap is tuned so
  the full-precision/binarized accuracy gap lands in the paper's bands
  (UPC: 96.2 → 88.6 %, UNSW: 90.3 → 85.3 %).

* The **ns-3 fat-tree** probe study is replaced by a queueing model of the
  same 2-pod CLOS (17 monitored queues, 19 distinct probe paths): bursty
  per-queue occupancies, probe one-way delays = sum of per-queue waits on
  the path, quantized to 8 bits.  Labels are per-queue threshold
  indicators, one binary classifier per queue, as in the paper's modified
  SIMON.  (The Rust crate contains the packet-level discrete-event
  fat-tree simulator used for the latency/throughput experiments; this
  module is its statistical twin for build-time training.)

All features are exported as uint16/uint8 vectors; bit expansion and ±1
mapping happen in ``binarize.featurize``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

N_FLOW_FEATURES = 16  # paper: 16 most important features (chi-squared)
N_PROBES = 19         # paper: 19 probes, one per distinct path
N_QUEUES = 17         # paper: 17 monitored output queues


@dataclass
class Dataset:
    """Quantized features + integer labels, with a train/test split."""

    x: np.ndarray        # uint16 [n, n_features] (tomography: uint8)
    y: np.ndarray        # int64 [n] class labels
    feature_bits: int    # 16 for flow features, 8 for probe delays
    name: str = ""

    def split(self, test_frac: float = 0.25, seed: int = 0):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(self.y))
        cut = int(len(idx) * (1 - test_frac))
        tr, te = idx[:cut], idx[cut:]
        return (self.x[tr], self.y[tr]), (self.x[te], self.y[te])


def _quantize16(v: np.ndarray) -> np.ndarray:
    return np.clip(v, 0, 65535).astype(np.uint16)


def _lognormal(rng, mean, sigma, n):
    return rng.lognormal(mean=np.log(mean), sigma=sigma, size=n)


def _flow_features(rng: np.random.Generator, n: int, profile: dict) -> np.ndarray:
    """Draw n flows of 16 quantized features from a class profile.

    Features (scaled into [0, 65535]): mean/min/max/std packet size, flow
    duration, total packets, total bytes, mean/std inter-arrival, up/down
    packet ratio, up/down byte ratio, src/dst port class, TCP flag mix,
    burstiness index.
    """
    f = np.empty((n, N_FLOW_FEATURES))
    ps_mean = _lognormal(rng, profile["pkt_size"], profile["pkt_sigma"], n)
    f[:, 0] = ps_mean * 40                               # mean pkt size
    f[:, 1] = np.maximum(ps_mean * 40 - rng.gamma(2.0, 300, n), 40 * 40)
    f[:, 2] = ps_mean * 40 + rng.gamma(2.0, profile["pkt_spread"], n)
    f[:, 3] = rng.gamma(2.0, profile["pkt_spread"] / 2, n)
    dur = _lognormal(rng, profile["duration"], 1.0, n)
    f[:, 4] = dur * 100                                  # duration
    pkts = _lognormal(rng, profile["pkts"], profile["pkts_sigma"], n)
    f[:, 5] = pkts * 20                                  # total pkts
    f[:, 6] = pkts * ps_mean * 2                         # total bytes
    iat = dur / np.maximum(pkts, 1)
    f[:, 7] = iat * 4000                                 # mean IAT
    f[:, 8] = iat * rng.gamma(2.0, profile["iat_jitter"], n) * 800
    updown = rng.beta(profile["up_a"], profile["up_b"], n)
    f[:, 9] = updown * 65535                             # up/down pkt ratio
    f[:, 10] = np.clip(updown + rng.normal(0, 0.08, n), 0, 1) * 65535
    f[:, 11] = rng.choice(profile["src_ports"], n) * 256 + rng.integers(0, 256, n)
    f[:, 12] = rng.choice(profile["dst_ports"], n) * 256 + rng.integers(0, 256, n)
    f[:, 13] = rng.binomial(8, profile["flag_p"], n) * 8192  # TCP flag mix
    f[:, 14] = rng.beta(profile["burst_a"], 2.0, n) * 65535  # burstiness
    f[:, 15] = np.abs(rng.normal(profile["entropy"], 0.12, n)) * 40000
    return _quantize16(f)


# Class profiles.  P2P: many small-to-medium packets, long flows, high port
# entropy, symmetric up/down.  "Other" is a mixture (web, dns, ssh, video).
_P2P = dict(pkt_size=21, pkt_sigma=0.55, pkt_spread=700, duration=20,
            pkts=20, pkts_sigma=0.9, iat_jitter=1.2, up_a=3, up_b=6,
            src_ports=np.arange(100, 250), dst_ports=np.arange(0, 250),
            flag_p=0.45, burst_a=2.2, entropy=1.0)
_WEB = dict(pkt_size=25, pkt_sigma=0.4, pkt_spread=900, duration=4,
            pkts=12, pkts_sigma=0.7, iat_jitter=1.0, up_a=2, up_b=8,
            src_ports=np.arange(100, 250), dst_ports=np.array([0, 1]),
            flag_p=0.55, burst_a=3.0, entropy=0.7)
_DNS = dict(pkt_size=3, pkt_sigma=0.3, pkt_spread=80, duration=0.3,
            pkts=2, pkts_sigma=0.3, iat_jitter=0.5, up_a=5, up_b=5,
            src_ports=np.arange(100, 250), dst_ports=np.array([2]),
            flag_p=0.05, burst_a=4.0, entropy=0.3)
_VIDEO = dict(pkt_size=33, pkt_sigma=0.25, pkt_spread=400, duration=120,
              pkts=200, pkts_sigma=0.6, iat_jitter=0.6, up_a=1, up_b=12,
              src_ports=np.arange(100, 250), dst_ports=np.array([0, 3]),
              flag_p=0.5, burst_a=2.0, entropy=0.5)

# Anomaly profiles: scans (tiny, bursty, wide dst ports), floods, exfil.
_BENIGN = dict(pkt_size=22, pkt_sigma=0.5, pkt_spread=700, duration=10,
               pkts=25, pkts_sigma=0.8, iat_jitter=1.0, up_a=3, up_b=6,
               src_ports=np.arange(100, 250), dst_ports=np.arange(0, 40),
               flag_p=0.5, burst_a=2.5, entropy=0.8)
_SCAN = dict(pkt_size=3, pkt_sigma=0.25, pkt_spread=60, duration=0.2,
             pkts=2, pkts_sigma=0.25, iat_jitter=0.3, up_a=9, up_b=1,
             src_ports=np.arange(100, 250), dst_ports=np.arange(0, 250),
             flag_p=0.12, burst_a=5.0, entropy=1.6)
_FLOOD = dict(pkt_size=6, pkt_sigma=0.3, pkt_spread=100, duration=30,
              pkts=500, pkts_sigma=0.5, iat_jitter=0.2, up_a=10, up_b=1,
              src_ports=np.arange(100, 250), dst_ports=np.array([0, 1]),
              flag_p=0.2, burst_a=0.8, entropy=1.1)
_EXFIL = dict(pkt_size=30, pkt_sigma=0.4, pkt_spread=600, duration=45,
              pkts=120, pkts_sigma=0.6, iat_jitter=0.8, up_a=11, up_b=2,
              src_ports=np.arange(100, 250), dst_ports=np.arange(0, 60),
              flag_p=0.45, burst_a=1.5, entropy=1.3)


def make_traffic_classification(n: int = 24000, seed: int = 1) -> Dataset:
    """UPC-AAU stand-in: P2P (class 1) vs mixture of other apps (class 0)."""
    rng = np.random.default_rng(seed)
    n_pos = n // 2
    pos = _flow_features(rng, n_pos, _P2P)
    mix = rng.choice(3, n - n_pos, p=[0.5, 0.2, 0.3])
    neg = np.concatenate([
        _flow_features(rng, int((mix == 0).sum()), _WEB),
        _flow_features(rng, int((mix == 1).sum()), _DNS),
        _flow_features(rng, int((mix == 2).sum()), _VIDEO),
    ])
    x = np.concatenate([pos, neg])
    y = np.concatenate([np.ones(n_pos, np.int64), np.zeros(len(neg), np.int64)])
    flip = rng.random(len(y)) < 0.02  # ground-truth (DPI) labeling noise
    y = np.where(flip, 1 - y, y)
    return Dataset(x=x, y=y, feature_bits=16, name="traffic")


def make_anomaly_detection(n: int = 24000, seed: int = 2) -> Dataset:
    """UNSW-NB15 stand-in: bad (scan/flood/exfil, class 1) vs good.

    Noisier than the traffic task (labels flip with small probability and
    attack profiles overlap benign ones), matching the paper's lower
    accuracies (90.3 % float / 85.3 % binary).
    """
    rng = np.random.default_rng(seed)
    n_bad = n // 2
    mix = rng.choice(3, n_bad, p=[0.45, 0.25, 0.3])
    bad = np.concatenate([
        _flow_features(rng, int((mix == 0).sum()), _SCAN),
        _flow_features(rng, int((mix == 1).sum()), _FLOOD),
        _flow_features(rng, int((mix == 2).sum()), _EXFIL),
    ])
    good = _flow_features(rng, n - n_bad, _BENIGN)
    x = np.concatenate([bad, good])
    y = np.concatenate([np.ones(len(bad), np.int64), np.zeros(len(good), np.int64)])
    flip = rng.random(len(y)) < 0.06  # label noise: real NIDS data is dirty
    y = np.where(flip, 1 - y, y)
    return Dataset(x=x, y=y, feature_bits=16, name="anomaly")


def probe_path_matrix(seed: int = 3) -> np.ndarray:
    """0/1 incidence matrix [N_PROBES, N_QUEUES]: which queues a probe crosses.

    Mirrors the 2-pod CLOS of Fig. 33: every probe traverses the source ToR
    uplink, possibly an aggregation/core pair, and the destination downlinks
    toward host 0.  Deterministic given the seed; the Rust fat-tree uses the
    same construction (cross-checked in integration tests).
    """
    rng = np.random.default_rng(seed)
    m = np.zeros((N_PROBES, N_QUEUES), dtype=np.int8)
    for p in range(N_PROBES):
        # 2–4 queues per path: ToR-up, [agg-up, core/agg-down,] ToR-down.
        hops = rng.choice(N_QUEUES, size=rng.integers(2, 5), replace=False)
        m[p, hops] = 1
    # Every queue must be observable by at least one probe.
    for q in range(N_QUEUES):
        if m[:, q].sum() == 0:
            m[rng.integers(0, N_PROBES), q] = 1
    return m


def make_tomography(n: int = 12000, seed: int = 4,
                    congested_frac: float = 0.25) -> tuple[Dataset, np.ndarray]:
    """SIMON stand-in: probe one-way delays → per-queue congestion labels.

    Returns ``(dataset, labels_all)`` where ``dataset.x`` is uint8
    [n, 19] quantized delays and ``labels_all`` is [n, 17] 0/1 congestion
    indicators (queue length above threshold).  ``dataset.y`` is queue 0's
    labels; callers slice ``labels_all`` for the other queues.
    """
    rng = np.random.default_rng(seed)
    paths = probe_path_matrix()
    # Bursty occupancy: AR(1) baseline + on/off incast bursts per queue.
    occ = np.zeros((n, N_QUEUES))
    state = rng.random(N_QUEUES) * 10
    burst = np.zeros(N_QUEUES, bool)
    for t in range(n):
        flip = rng.random(N_QUEUES)
        burst = np.where(burst, flip > 0.30, flip < 0.09)  # sticky bursts
        target = np.where(burst, rng.gamma(8.0, 16.0, N_QUEUES),
                          rng.gamma(1.5, 3.0, N_QUEUES))
        state = 0.45 * state + 0.55 * target
        occ[t] = state
    thr = np.quantile(occ, 1 - congested_frac, axis=0)
    labels_all = (occ > thr).astype(np.int64)
    # One-way delay: propagation + sum of per-queue waits + measurement noise.
    delays = occ @ paths.T.astype(float)
    delays = delays + rng.normal(0, 0.8, delays.shape) + 4.0
    # Quantize to 8b over the p99 dynamic range (as the NIC would, with a
    # calibrated clamp): scaling to the absolute max would crush typical
    # delays into a handful of levels during rare multi-queue bursts.
    scale = np.quantile(delays, 0.99)
    x = np.clip(delays * 255 / max(scale, 1e-9), 0, 255).astype(np.uint8)
    ds = Dataset(x=x, y=labels_all[:, 0], feature_bits=8, name="tomography")
    return ds, labels_all

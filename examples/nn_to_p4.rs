//! NNtoP4 demo: compile a trained BNN to a PISA pipeline, verify the
//! pipeline interpreter against the reference executor bit-for-bit, show
//! the scaling wall, and print a slice of the generated P4₁₆ source.
//! Run: `cargo run --release --example nn_to_p4`.

use n3ic::bnn::{infer_scores, BnnLayer, BnnModel};
use n3ic::pisa::{compile_bnn, p4gen, PisaResources};

fn main() -> n3ic::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::var("N3IC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let model = BnnModel::load_named(&artifacts, "traffic")
        .unwrap_or_else(|_| BnnModel::random("traffic", 256, &[32, 16, 2], 1));

    let prog = compile_bnn(&model).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "compiled {}: {} PHV fields, {} stages, {} ALU ops",
        model.describe(),
        prog.phv_fields,
        prog.stages.len(),
        prog.total_ops()
    );

    // Bit-exact functional test (what bmv2 does in the paper).
    let mut checked = 0;
    for seed in 0..50 {
        let x = BnnLayer::random(1, 256, 7_000 + seed).words;
        assert_eq!(prog.run(&x), infer_scores(&model, &x));
        checked += 1;
    }
    println!("pipeline interpreter == reference executor on {checked} random inputs");

    // Resources + latency + the scaling wall.
    let res = PisaResources::for_model(&model).design;
    println!(
        "resources: {:.1}k LUT ({:.1}%), {} BRAM ({:.1}%) — Table 2's N3IC-P4 row",
        res.lut as f64 / 1000.0,
        res.lut_pct(),
        res.bram,
        res.bram_pct()
    );
    println!("pipeline latency: {:.2} us", prog.latency_ns(64) / 1000.0);
    let big = BnnModel::random("fc128", 256, &[128], 1);
    match compile_bnn(&big) {
        Err(e) => println!("scaling wall reproduced: 128-neuron FC → {e}"),
        Ok(_) => println!("unexpected: 128-neuron FC compiled"),
    }

    // Show the P4 source head + tail.
    let p4 = p4gen::to_p4(&model, &prog);
    let lines: Vec<&str> = p4.lines().collect();
    println!("\n---- generated P4 ({} lines) ----", lines.len());
    for l in &lines[..18.min(lines.len())] {
        println!("{l}");
    }
    println!("...");
    for l in &lines[lines.len().saturating_sub(6)..] {
        println!("{l}");
    }
    Ok(())
}

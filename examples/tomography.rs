//! Network tomography end to end (§5 #3): run the fat-tree simulator,
//! collect probe one-way delays, infer per-queue congestion with the
//! deployed BNN + calibrated detectors, and check the real-time budgets
//! of Fig. 15.  Run: `cargo run --release --example tomography`.

use n3ic::bnn::BnnModel;
use n3ic::bnnexec::HostCostModel;
use n3ic::fpga::FpgaTiming;
use n3ic::nfp::{DataParallelCost, MemKind};
use n3ic::tomography::{
    meets_deadline, TomographyRun, PROBE_PERIOD_100G_NS, PROBE_PERIOD_400G_NS,
    PROBE_PERIOD_40G_NS,
};

fn main() -> n3ic::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::var("N3IC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let model = BnnModel::load_named(&artifacts, "tomography_128")
        .unwrap_or_else(|_| BnnModel::random("tomography_128", 152, &[128, 64, 2], 1));
    println!(
        "model: {} ({} bytes; trained bin acc {:.1}%)",
        model.describe(),
        model.memory_bytes(),
        model.metrics.bnn_test_acc * 100.0
    );

    // --- run the fat-tree + probes + inference pipeline -----------------
    let run = TomographyRun::default();
    let rep = run.evaluate(&model, 400);
    println!("\n== fat-tree probe study ({} rounds evaluated) ==", rep.rounds);
    let mut accs = rep.accuracy.clone();
    accs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "per-queue congestion accuracy: min {:.3} / med {:.3} / max {:.3}",
        accs[0],
        rep.median_accuracy,
        accs[accs.len() - 1]
    );
    println!(
        "deployed BNN on queue 0 (trained on the statistical twin): {:.3}",
        rep.bnn_q0_accuracy
    );

    // --- the Fig. 15 real-time story ------------------------------------
    println!("\n== probe-period budgets (Fig. 15) ==");
    let budgets = [
        ("40G / 250us", PROBE_PERIOD_40G_NS),
        ("100G / 100us", PROBE_PERIOD_100G_NS),
        ("400G / 25us", PROBE_PERIOD_400G_NS),
    ];
    let host = HostCostModel::default().batch_latency_ns(&model, 1);
    // ×1.7: several per-queue NNs share the NFP thread pool (§7).
    let nfp = DataParallelCost::new(&model, MemKind::Cls).mean_ns() * 1.7;
    let fpga = FpgaTiming::new(&model).latency_ns();
    for (name, lat, nns) in [
        ("bnn-exec", host, 1usize),
        ("N3IC-NFP", nfp, 1),
        ("N3IC-FPGA", fpga, 8), // one module serializes several queue NNs
    ] {
        print!("{name:10} ({:7.1}us x{nns}):", lat / 1000.0);
        for (bn, budget) in budgets {
            print!(
                "  {bn}={}",
                if meets_deadline(lat, nns, budget) { "ok" } else { "MISS" }
            );
        }
        println!();
    }
    println!("\nshape check: only N3IC-FPGA meets the 400G probe budget (Result 2)");
    Ok(())
}

//! End-to-end traffic-analysis driver (§6.1 + the flow-shunting use case):
//! generated 40Gb/s@256B traffic → flow table + statistics → trigger at
//! 10 packets/flow → NIC-side BNN (N3IC-FPGA model) → shunting split,
//! with the host `bnn-exec` cost model as the comparison term.
//!
//! This is the repository's end-to-end validation workload (DESIGN.md):
//! it exercises packets, flows, features, the coordinator, the executor
//! and the metrics stack on one realistic scenario and prints the same
//! quantities Figs. 13/14 report.  Run: `cargo run --release --example
//! traffic_analysis [n_packets]`.

use n3ic::bnn::BnnModel;
use n3ic::bnnexec::HostCostModel;
use n3ic::coordinator::{
    CoreExecutor, NnExecutor, PacketEvent, ShuntDecision, ShuntRouter,
};
use n3ic::metrics::LatencyHistogram;
use n3ic::net::features::FeatureVector;
use n3ic::net::flow::FlowTable;
use n3ic::net::traffic::{CbrSpec, TrafficGen};
use n3ic::nfp::{MemKind, NfpSim};

fn main() -> n3ic::Result<()> {
    let n_packets: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000_000);
    let artifacts = std::path::PathBuf::from(
        std::env::var("N3IC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let model = BnnModel::load_named(&artifacts, "traffic")
        .unwrap_or_else(|_| BnnModel::random("traffic", 256, &[32, 16, 2], 1));

    // --- the real pipeline: packets → flows → features → NIC BNN -------
    let spec = CbrSpec { gbps: 40.0, pkt_size: 256 };
    let mut gen = TrafficGen::new(spec, 200_000, 42);
    let mut flows = FlowTable::new(1 << 19);
    let mut router = ShuntRouter::new(CoreExecutor::fpga(model.clone()), 1);
    let mut device_latency = LatencyHistogram::new();
    let trigger_pkts = 10;

    let t0 = std::time::Instant::now();
    let mut inferences = 0u64;
    for _ in 0..n_packets {
        let p = gen.next_packet();
        if let Some(up) = flows.update(&p) {
            if up.pkts == trigger_pkts {
                let x = FeatureVector::from_stats(up.stats).pack();
                let _decision: ShuntDecision = router.route(&x);
                device_latency.record(router.nic_exec.latency_ns());
                inferences += 1;
            }
        }
        let _ = PacketEvent { packet: p, payload_words: None }; // shape check
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("== end-to-end traffic analysis ==");
    println!("offered          : 40Gb/s@256B = {:.1} Mpps", spec.pps() / 1e6);
    println!("packets processed: {n_packets} in {wall:.2}s host wall");
    println!(
        "pipeline rate    : {:.2} Mpps ({:.1}x line rate on one host core)",
        n_packets as f64 / wall / 1e6,
        n_packets as f64 / wall / spec.pps()
    );
    println!("flows tracked    : {}", flows.len());
    println!("nn inferences    : {inferences}");
    println!(
        "shunting         : {:.1}% kept on NIC, {:.1}% to host",
        router.stats.offload_ratio() * 100.0,
        100.0 - router.stats.offload_ratio() * 100.0
    );
    println!(
        "device latency   : p50 {:.2}us p95 {:.2}us (N3IC-FPGA model)",
        device_latency.p50_us(),
        device_latency.p95_us()
    );

    // --- paper-scale comparison (Figs. 13/14) ---------------------------
    println!("\n== modeled comparison at 1.81M flows/s offered ==");
    let offered = 1.81e6;
    let nfp = NfpSim::new(&model, MemKind::Cls, 480).run(offered, 150_000, 1);
    println!(
        "N3IC-NFP  : {:.2}M flows/s, p95 {:.0}us, fwd {:.1} Mpps",
        nfp.completed_per_sec / 1e6,
        nfp.latency.p95_us(),
        nfp.forwarding_mpps
    );
    let fpga_lat = router.nic_exec.latency_ns() / 1000.0;
    println!("N3IC-FPGA : matches offered (1 module ≈ 1.8M/s), p95 {fpga_lat:.2}us");
    let host = HostCostModel::default();
    for b in [1usize, 1000, 10_000] {
        println!(
            "bnn-exec b={b:<6}: {:.2}M flows/s, latency {:.0}us",
            host.throughput_per_sec(&model, b) / 1e6,
            host.batch_latency_ns(&model, b) / 1000.0
        );
    }
    println!("\nshape check: N3IC ≥1.5x bnn-exec throughput at 10-100x lower latency");
    Ok(())
}

//! Quickstart: load a trained BNN, classify packed inputs, and verify the
//! whole stack end to end — Rust core vs Pallas goldens vs the AOT/PJRT
//! artifact.
//!
//! Run with: `cargo run --release --example quickstart`
//! (after `make artifacts`).

use n3ic::bnn::{infer_scores, load_golden, BnnModel};
use n3ic::runtime::{Manifest, PjrtRuntime};

fn main() -> n3ic::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::var("N3IC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let model = BnnModel::load_named(&artifacts, "traffic")?;
    println!(
        "model: {} {} — {} bytes packed, bin acc {:.1}% (float {:.1}%)",
        model.name,
        model.describe(),
        model.memory_bytes(),
        model.metrics.bnn_test_acc * 100.0,
        model.metrics.float_test_acc * 100.0
    );

    // 1. Rust core vs the Pallas-kernel goldens exported at build time.
    let golden = load_golden(&artifacts, "traffic")?;
    let mut agree = 0;
    for (x, want) in golden.inputs.iter().zip(&golden.scores) {
        let got = infer_scores(&model, x);
        assert_eq!(&got, want, "core executor diverged from Pallas kernel");
        agree += 1;
    }
    println!("rust core == pallas golden on {agree}/{} vectors", golden.inputs.len());

    // 2. The AOT artifact through PJRT (the runtime the coordinator uses).
    let mut rt = PjrtRuntime::new(&artifacts)?;
    println!("pjrt platform: {}", rt.platform());
    let key = Manifest::key_for(&model, 1);
    for (x, want) in golden.inputs.iter().zip(&golden.scores).take(4) {
        let got = rt.infer_batch(&key, &model, std::slice::from_ref(x))?;
        assert_eq!(&got[0], want, "PJRT artifact diverged");
    }
    println!("pjrt artifact {key} == goldens — three layers agree bit-for-bit");

    // 3. Classify something.
    let x = &golden.inputs[0];
    let scores = infer_scores(&model, x);
    println!(
        "example inference: scores={scores:?} → class {}",
        scores
            .iter()
            .enumerate()
            .max_by_key(|(_, s)| **s)
            .map(|(i, _)| i)
            .unwrap()
    );
    Ok(())
}

#!/usr/bin/env bash
# One-command gate for every PR: tier-1 build + tests, then the perf
# benches in smoke mode (10x-shortened budgets; exercises every bench
# body and regenerates BENCH.json without publication-grade numbers).
#
#   ./scripts/verify.sh
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== perf smoke: executors bench =="
N3IC_BENCH_SMOKE=1 cargo bench --bench executors

echo "== perf smoke: batch_engine bench (writes BENCH.smoke.json) =="
# Smoke runs write BENCH.smoke.json (gitignored) so they never clobber
# the tracked BENCH.json.  For a gating full-length run use:
#   N3IC_BENCH_ENFORCE=1 cargo bench --bench batch_engine
# (smoke numbers are too noisy to gate on, so enforcement is off here).
N3IC_BENCH_SMOKE=1 cargo bench --bench batch_engine

echo "verify.sh: all gates passed"

#!/usr/bin/env bash
# One-command gate for every PR: lint + tier-1 build + tests, then the
# perf benches in smoke mode (10x-shortened budgets; exercises every
# bench body and regenerates BENCH.smoke.json without publication-grade
# numbers).  The smoke run of the `pipeline` bench doubles as the
# serial-vs-pipelined determinism gate (it asserts bit-identical verdict
# histograms before timing anything).
#
#   ./scripts/verify.sh
set -euo pipefail

cd "$(dirname "$0")/../rust"

# Lint gates (hard failures where the toolchain components exist; hosts
# without rustfmt/clippy skip them loudly rather than silently passing).
if cargo fmt --version >/dev/null 2>&1; then
  echo "== lint: cargo fmt --check =="
  cargo fmt --check
else
  echo "== lint: cargo fmt not installed — SKIPPED (install rustfmt) =="
fi
if cargo clippy --version >/dev/null 2>&1; then
  echo "== lint: cargo clippy -D warnings =="
  cargo clippy --all-targets -- -D warnings
  # The legacy serving API (CoordinatorService & friends) survives one
  # PR as deprecated shims for out-of-tree users only: no in-repo test
  # or bench may keep using it.  Scoped to tests/benches; the shims
  # themselves live under a module-level allow(deprecated).
  echo "== lint: cargo clippy --tests --benches -D deprecated (no in-repo legacy callers) =="
  cargo clippy --tests --benches -- -D deprecated
else
  echo "== lint: cargo clippy not installed — SKIPPED (install clippy) =="
fi

# Docs are API surface now (the InferencePlane/ServeBuilder redesign):
# lib.rs denies rustdoc::broken_intra_doc_links, so a stale link fails
# this build.
echo "== docs: cargo doc --no-deps (broken intra-doc links are errors) =="
cargo doc --no-deps --quiet

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== perf smoke: executors bench =="
N3IC_BENCH_SMOKE=1 cargo bench --bench executors

# Smoke runs write BENCH.smoke.json (gitignored) so they never clobber
# the tracked BENCH.json.  For a gating full-length run use:
#   N3IC_BENCH_ENFORCE=1 cargo bench --bench batch_engine
# (smoke numbers are too noisy to gate on, so enforcement is off here).
echo "== perf smoke: batch_engine bench (merges into BENCH.smoke.json) =="
N3IC_BENCH_SMOKE=1 cargo bench --bench batch_engine

# Asserts serial-vs-pipelined verdict equivalence, then times the grid.
echo "== perf smoke + equivalence: pipeline bench =="
N3IC_BENCH_SMOKE=1 cargo bench --bench pipeline

# Registry pin/publish/swap-storm costs (hot-swap overhead record).
echo "== perf smoke: registry bench =="
N3IC_BENCH_SMOKE=1 cargo bench --bench registry

echo "verify.sh: all gates passed"

#!/usr/bin/env bash
# One-command gate for every PR: lint + tier-1 build + tests, then the
# perf benches in smoke mode (10x-shortened budgets; exercises every
# bench body and regenerates BENCH.smoke.json without publication-grade
# numbers).  The smoke run of the `pipeline` bench doubles as the
# serial-vs-pipelined determinism gate (it asserts bit-identical verdict
# histograms before timing anything).
#
#   ./scripts/verify.sh
set -euo pipefail

cd "$(dirname "$0")/../rust"

# Lint gates (hard failures where the toolchain components exist; hosts
# without rustfmt/clippy skip them loudly rather than silently passing).
if cargo fmt --version >/dev/null 2>&1; then
  echo "== lint: cargo fmt --check =="
  cargo fmt --check
else
  echo "== lint: cargo fmt not installed — SKIPPED (install rustfmt) =="
fi
if cargo clippy --version >/dev/null 2>&1; then
  echo "== lint: cargo clippy -D warnings =="
  cargo clippy --all-targets -- -D warnings
  echo "== lint: cargo clippy -D warnings (--features simd) =="
  cargo clippy --all-targets --features simd -- -D warnings
else
  echo "== lint: cargo clippy not installed — SKIPPED (install clippy) =="
fi

# Docs are API surface now (the InferencePlane/ServeBuilder redesign):
# lib.rs denies rustdoc::broken_intra_doc_links, so a stale link fails
# this build.
echo "== docs: cargo doc --no-deps (broken intra-doc links are errors) =="
cargo doc --no-deps --quiet

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

# The SIMD feature set is a first-class build: the AVX2 kernel must
# compile AND pass the whole suite (the differential fuzzer compares it
# bit-for-bit against the scalar path on every fuzzed shape; on hosts
# without AVX2 it degrades to scalar-vs-scalar, still a valid build
# gate).
echo "== tier-1: cargo build --release --features simd =="
cargo build --release --features simd

echo "== tier-1: cargo test -q --features simd =="
cargo test -q --features simd

echo "== perf smoke: executors bench =="
N3IC_BENCH_SMOKE=1 cargo bench --bench executors

# Smoke runs write BENCH.smoke.json (gitignored) so they never clobber
# the tracked BENCH.json.  For a gating full-length run use:
#   N3IC_BENCH_ENFORCE=1 cargo bench --bench batch_engine
# (smoke numbers are too noisy to gate on, so enforcement is off here).
echo "== perf smoke: batch_engine bench (merges into BENCH.smoke.json) =="
N3IC_BENCH_SMOKE=1 cargo bench --bench batch_engine

# Asserts serial-vs-pipelined verdict equivalence, then times the grid.
echo "== perf smoke + equivalence: pipeline bench =="
N3IC_BENCH_SMOKE=1 cargo bench --bench pipeline

# Registry pin/publish/swap-storm costs (hot-swap overhead record).
echo "== perf smoke: registry bench =="
N3IC_BENCH_SMOKE=1 cargo bench --bench registry

# Admission / degradation / failover costs (overload control record).
echo "== perf smoke: overload bench =="
N3IC_BENCH_SMOKE=1 cargo bench --bench overload

# Flow-table scale grid, smoke cell (tiny working set, BENCH.smoke.json;
# the bench itself asserts evictions > 0, so a silently-unbounded table
# fails here).
echo "== perf smoke: scale bench =="
N3IC_BENCH_SMOKE=1 cargo bench --bench scale

# The acceptance cell of the scale grid: one bounded 1M-flow churn run,
# recorded into the *tracked* BENCH.json (no smoke env on purpose).
echo "== perf: scale grid CI cell (1M flows, writes tracked BENCH.json) =="
N3IC_SCALE_GRID=ci cargo bench --bench scale
grep -q '"scale"' ../BENCH.json \
  || { echo "scale bench: no 'scale' entry in BENCH.json"; exit 1; }

# Churn CLI smoke: a capped table under forced churn must finish without
# panicking (the pre-eviction table died here) and report evictions.
echo "== scale smoke: churn against a capped table reports evictions =="
churn_out=$(cargo run --release --quiet -- serve --backend host \
  --packets 200000 --flows 50000 --table-cap 4096 --churn 0.5 \
  --trigger-pkts 5)
echo "$churn_out"
echo "$churn_out" | grep -Eq "evictions=[1-9]" \
  || { echo "scale smoke: expected evictions > 0"; exit 1; }

# Overload CLI smoke: a seeded 40 Gb/s burst against the slow host
# backend must trip the admission controller and walk the degradation
# ladder down AND back up (the tail of the run drains the backlog), all
# on the deterministic packet clock — any change in that behavior shows
# up here before it ships.
echo "== overload smoke: seeded burst trips shedding + ladder round trip =="
overload_out=$(cargo run --release --quiet -- serve --backend host \
  --packets 300000 --flows 1500 --trigger-pkts 10 \
  --shed-policy 500:100 --degrade on)
echo "$overload_out"
echo "$overload_out" | grep -Eq "sheds *: *[1-9]" \
  || { echo "overload smoke: expected sheds > 0"; exit 1; }
echo "$overload_out" | grep -q "step-down" \
  || { echo "overload smoke: expected a ladder step-down"; exit 1; }
echo "$overload_out" | grep -q "step-up" \
  || { echo "overload smoke: expected a ladder step-up (recovery)"; exit 1; }

# Scenario smoke: every registered scenario — the three §5 use cases
# plus the online-learning `drift` loop — runs seeded and small through
# the unified service, serial AND pipelined.  Each run must clear its
# accuracy floor (the CLI exits nonzero and prints FAIL otherwise), and
# the pipelined run must reproduce the serial run's order-independent
# verdict digest — the determinism contract checked end-to-end through
# the scenario subsystem, drift's live republishes included.
echo "== scenario smoke: all use cases, floor + serial≡pipelined digest =="
for sc in traffic anomaly tomography drift; do
  if [ "$sc" = tomography ]; then ev=160; else ev=8000; fi
  serial_out=$(cargo run --release --quiet -- scenario "$sc" --events "$ev")
  echo "$serial_out"
  echo "$serial_out" | grep -q "PASS" \
    || { echo "scenario smoke: $sc serial did not PASS its floor"; exit 1; }
  piped_out=$(cargo run --release --quiet -- scenario "$sc" --events "$ev" \
    --pipeline 3 --batch 8)
  echo "$piped_out" | grep -q "PASS" \
    || { echo "scenario smoke: $sc pipelined did not PASS its floor"; exit 1; }
  d_serial=$(echo "$serial_out" | grep "verdict digest")
  d_piped=$(echo "$piped_out" | grep "verdict digest")
  [ -n "$d_serial" ] && [ "$d_serial" = "$d_piped" ] \
    || { echo "scenario smoke: $sc digest mismatch: '$d_serial' vs '$d_piped'"; exit 1; }
  if [ "$sc" = drift ]; then
    # The learning loop's own invariants: Page–Hinkley fired after the
    # recipe shift, and windowed accuracy recovered post-republish.
    echo "$serial_out" | grep -Eq "drift check *:.*PASS" \
      || { echo "drift smoke: detector never fired"; exit 1; }
    echo "$serial_out" | grep -Eq "recovery check *:.*PASS" \
      || { echo "drift smoke: accuracy did not recover"; exit 1; }
  fi
done

# Gate fault injection: sabotaged candidates must all be rejected (the
# promotion gate earns its keep), and a bad candidate forced past the
# gate must be rolled back by probation.  Both modes print their own
# `gate check : … PASS` line and exit nonzero on failure.
echo "== drift smoke: gate rejects sabotage, probation rolls back forced publish =="
sab_out=$(cargo run --release --quiet -- scenario drift --events 8000 \
  --gate sabotage)
echo "$sab_out"
echo "$sab_out" | grep -Eq "gate check *:.*PASS" \
  || { echo "drift smoke: sabotage gate check failed"; exit 1; }
force_out=$(cargo run --release --quiet -- scenario drift --events 8000 \
  --gate force-accept)
echo "$force_out"
echo "$force_out" | grep -Eq "gate check *:.*PASS" \
  || { echo "drift smoke: force-accept rollback check failed"; exit 1; }

# Quantized-MLP backend smoke: the fixed-point executor must clear the
# traffic-classification floor through the same scenario CLI (its
# verdict-equality with the BNN planes is asserted in the test suite;
# this gate proves the wiring end to end).
echo "== qmlp smoke: traffic scenario on the fixed-point backend =="
qmlp_out=$(cargo run --release --quiet -- scenario traffic --events 8000 \
  --backend qmlp)
echo "$qmlp_out"
echo "$qmlp_out" | grep -q "PASS" \
  || { echo "qmlp smoke: traffic on qmlp did not PASS its floor"; exit 1; }

# Per-scenario throughput record (smoke cells assert each floor too).
echo "== perf smoke: scenario bench =="
N3IC_BENCH_SMOKE=1 cargo bench --bench scenario

# The tracked per-scenario throughput entry in BENCH.json.
echo "== perf: scenario bench (writes tracked BENCH.json) =="
cargo bench --bench scenario
grep -q '"scenario"' ../BENCH.json \
  || { echo "scenario bench: no 'scenario' entry in BENCH.json"; exit 1; }

# Kernel-path grid (scalar vs AVX2 vs qmlp), smoke first, then the
# tracked GOPS/inputs-per-sec record.  Built with the simd feature so
# the vector rows are real where the host has AVX2; BENCH.json records
# `simd_compiled`/`simd_available` so a scalar-only host is visible in
# the data instead of silently passing.
echo "== perf smoke: simd bench (--features simd) =="
N3IC_BENCH_SMOKE=1 cargo bench --bench simd --features simd

echo "== perf: simd bench (writes tracked BENCH.json) =="
cargo bench --bench simd --features simd
grep -q '"simd"' ../BENCH.json \
  || { echo "simd bench: no 'simd' entry in BENCH.json"; exit 1; }

# Online-learning cost record: refit latency + the drift loop's
# end-to-end throughput (the bench itself asserts the floor and at
# least one live promotion).  Smoke first, then the tracked entry.
echo "== perf smoke: learn bench =="
N3IC_BENCH_SMOKE=1 cargo bench --bench learn

echo "== perf: learn bench (writes tracked BENCH.json) =="
cargo bench --bench learn
grep -q '"learn"' ../BENCH.json \
  || { echo "learn bench: no 'learn' entry in BENCH.json"; exit 1; }

echo "verify.sh: all gates passed"
